//! The single-world baseline ("SW"): the whole job lives in one CCL
//! world, used exactly as vanilla `torch.distributed` would be — no
//! manager, no watchdog, no multi-world state.
//!
//! Two consequences the experiments measure:
//!
//! * **Throughput**: SW is the floor MultiWorld's overhead is judged
//!   against (Figs 6 and 7: MW within 1.4–4.3% of SW in most cases).
//! * **Fault domain**: any worker death breaks the single world and the
//!   whole job stops (Fig 4, left); recovery means re-initializing
//!   everyone.

use crate::mwccl::{CclError, CclResult, Rendezvous, World, WorldOptions};
use crate::tensor::Tensor;

/// A single-world job: N ranks in one world, rank 0 acting as the
/// leader/sink (the Fig 4/7 shape).
pub struct SingleWorldJob {
    pub worlds: Vec<World>,
}

impl SingleWorldJob {
    /// Bring up all ranks in one process (threads) — transports are the
    /// real ones.
    pub fn start(name: &str, size: usize, opts: WorldOptions) -> CclResult<SingleWorldJob> {
        Ok(SingleWorldJob { worlds: Rendezvous::single_process(name, size, opts)? })
    }

    pub fn leader(&self) -> &World {
        &self.worlds[0]
    }

    pub fn rank(&self, r: usize) -> &World {
        &self.worlds[r]
    }

    /// Take ownership of one rank's handle (to drop it = kill it).
    pub fn take_rank(&mut self, r: usize) -> World {
        self.worlds.remove(r)
    }

    /// The restart-the-world recovery path CCL forces on you: abort
    /// everything and rendezvous a fresh world (new name — CCL worlds
    /// are not reusable). Returns the new job; callers measure how long
    /// service was unavailable.
    pub fn restart(self, new_name: &str, size: usize, opts: WorldOptions) -> CclResult<SingleWorldJob> {
        for w in &self.worlds {
            w.abort("single-world restart");
        }
        drop(self);
        SingleWorldJob::start(new_name, size, opts)
    }
}

/// One sender→receiver hop measured the SW way: plain world, blocking
/// ops, zero MultiWorld machinery. Returns bytes moved.
pub fn sw_send_recv(sender: &World, receiver: &World, t: Tensor, tag: u64) -> CclResult<u64> {
    let bytes = t.byte_len() as u64;
    let send = sender.isend(t, receiver.rank(), tag);
    // Blocking receive on the receiver side.
    let got = receiver.recv(sender.rank(), tag)?;
    send.wait()?;
    if got.byte_len() as u64 != bytes {
        return Err(CclError::Transport("byte count mismatch".into()));
    }
    Ok(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::time::Duration;

    fn uniq(n: &str) -> String {
        static C: AtomicU64 = AtomicU64::new(0);
        format!("sw-{n}-{}-{}", std::process::id(), C.fetch_add(1, Ordering::Relaxed))
    }

    #[test]
    fn traffic_flows() {
        let job = SingleWorldJob::start(&uniq("flow"), 2, WorldOptions::shm()).unwrap();
        let mut rng = Rng::new(1);
        let t = Tensor::f32_1d(1000, &mut rng);
        let c = t.checksum();
        let w1 = job.rank(1).clone();
        let h = std::thread::spawn(move || w1.send(t, 0, 1).unwrap());
        assert_eq!(job.leader().recv(1, 1).unwrap().checksum(), c);
        h.join().unwrap();
    }

    #[test]
    fn one_death_breaks_everyone() {
        // The Fig 4 (left) semantics: kill rank 2, rank 0 stops hearing
        // from ANYONE (the world is broken), even from the healthy rank 1.
        let mut job = SingleWorldJob::start(&uniq("death"), 3, WorldOptions::tcp()).unwrap();
        // Leader posts receives to BOTH workers (the Fig 4 leader loop).
        let r1 = job.leader().irecv(1, 1);
        let r2 = job.leader().irecv(2, 1);
        let victim = job.take_rank(2);
        drop(victim);
        // The dead member's socket reset fails its receive…
        assert!(r2.wait().is_err());
        // …which breaks the WHOLE world (single fault domain): the
        // receive from the perfectly healthy rank 1 dies too, and no
        // further ops are possible.
        assert!(r1.wait().is_err(), "healthy peer's recv must die with the world");
        assert!(job.leader().is_broken());
        let res = job
            .leader()
            .isend(Tensor::from_f32(&[1], &[1.0]), 1, 9)
            .wait();
        assert!(matches!(res, Err(CclError::WorldBroken(_))));
    }

    #[test]
    fn restart_recovers_service() {
        let job = SingleWorldJob::start(&uniq("r1"), 2, WorldOptions::shm()).unwrap();
        let job = job.restart(&uniq("r2"), 2, WorldOptions::shm()).unwrap();
        let w1 = job.rank(1).clone();
        let h = std::thread::spawn(move || w1.send(Tensor::from_f32(&[1], &[2.0]), 0, 1).unwrap());
        assert_eq!(job.leader().recv(1, 1).unwrap().as_f32(), &[2.0]);
        h.join().unwrap();
    }

    #[test]
    fn sw_send_recv_counts_bytes() {
        let job = SingleWorldJob::start(&uniq("bytes"), 2, WorldOptions::shm()).unwrap();
        let mut rng = Rng::new(2);
        let t = Tensor::f32_1d(256, &mut rng);
        let sender = job.rank(1).clone();
        let receiver = job.leader().clone();
        let h = std::thread::spawn(move || {
            // sw_send_recv drives both sides; run it in one thread with
            // handles to both (they're thread-safe).
            sw_send_recv(&sender, &receiver, t, 5).unwrap()
        });
        let bytes = h.join().unwrap();
        assert_eq!(bytes, 1024);
        let _ = Duration::ZERO;
    }
}
