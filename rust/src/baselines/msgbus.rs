//! The Fig. 1 baseline: forwarding tensors through a Kafka-style
//! message bus instead of a CCL.
//!
//! A [`Broker`] is a TCP server holding named topics (append-only
//! in-memory logs with consumer offsets — the Kafka shape without the
//! disk). Producers PUBLISH length-prefixed records; consumers FETCH
//! with long-polling.
//!
//! What makes the bus slow for tensors is not the broker — it's the
//! mandatory staging: the tensor must leave device memory, be
//! serialized, cross two sockets, and be deserialized + copied back.
//! The paper measures "up to 45% of the sender's time … copying the
//! tensor from GPU memory to CPU memory and then serializing it" and
//! 53% on the receiver. We reproduce the *device copy* with
//! [`DeviceStage`], a bandwidth-throttled memcpy (default 3 GB/s ≈
//! pageable-host PCIe copy — DESIGN.md documents the substitution); the
//! serialize step is the real tensor framing.
//!
//! Protocol: `op:u8 topic_len:u16 topic bytes_len:u32 bytes`
//!   op 1 = PUBLISH → resp `status:u8`
//!   op 2 = FETCH (bytes = offset:u64 timeout_ms:u32) →
//!          resp `status:u8 bytes_len:u32 bytes` (status 1 = timeout)

use crate::tensor::{read_tensor, write_tensor, Tensor};
use std::collections::HashMap;
use std::io::{BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

// ------------------------------------------------------------- DeviceStage

/// Simulated device↔host staging copy: a real memcpy, throttled to the
/// configured bandwidth to model the PCIe transfer the paper's testbed
/// pays on both ends.
pub struct DeviceStage {
    bandwidth_bps: f64,
}

impl DeviceStage {
    pub fn new(bandwidth_bps: f64) -> Self {
        assert!(bandwidth_bps > 0.0);
        DeviceStage { bandwidth_bps }
    }

    /// Default ≈ pageable cudaMemcpy over PCIe 3.0.
    pub fn pcie() -> Self {
        Self::new(3.0e9)
    }

    /// "Copy to host": memcpy + pacing. Returns the staged bytes.
    pub fn to_host(&self, t: &Tensor) -> Vec<u8> {
        let t0 = Instant::now();
        let staged = t.bytes().to_vec(); // the real copy
        self.pace(t.byte_len(), t0);
        staged
    }

    /// "Copy to device": memcpy + pacing.
    pub fn to_device(&self, bytes: &[u8]) -> Vec<u8> {
        let t0 = Instant::now();
        let copied = bytes.to_vec();
        self.pace(bytes.len(), t0);
        copied
    }

    fn pace(&self, n: usize, since: Instant) {
        let budget = Duration::from_secs_f64(n as f64 / self.bandwidth_bps);
        let spent = since.elapsed();
        if budget > spent {
            std::thread::sleep(budget - spent);
        }
    }
}

// ------------------------------------------------------------------ Broker

#[derive(Default)]
struct Topic {
    records: Vec<Arc<Vec<u8>>>,
}

#[derive(Default)]
struct BrokerState {
    topics: Mutex<HashMap<String, Topic>>,
    appended: Condvar,
}

/// In-memory single-node broker.
pub struct Broker {
    addr: SocketAddr,
    state: Arc<BrokerState>,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl Broker {
    pub fn start() -> anyhow::Result<Broker> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let state = Arc::new(BrokerState::default());
        let stop = Arc::new(AtomicBool::new(false));
        let (s2, st2) = (state.clone(), stop.clone());
        let accept = std::thread::Builder::new()
            .name("broker-accept".into())
            .spawn(move || {
                while !st2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((conn, _)) => {
                            let s3 = s2.clone();
                            let st3 = st2.clone();
                            let _ = std::thread::Builder::new()
                                .name("broker-conn".into())
                                .spawn(move || serve_conn(conn, s3, st3));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Broker { addr, state, stop, accept: Some(accept) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Records currently held in `topic`.
    pub fn topic_len(&self, topic: &str) -> usize {
        self.state
            .topics
            .lock()
            .unwrap()
            .get(topic)
            .map(|t| t.records.len())
            .unwrap_or(0)
    }
}

impl Drop for Broker {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.state.appended.notify_all();
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

fn serve_conn(conn: TcpStream, state: Arc<BrokerState>, stop: Arc<AtomicBool>) {
    let _ = conn.set_nodelay(true);
    let mut writer = match conn.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(conn);
    loop {
        let mut hdr = [0u8; 3];
        if reader.read_exact(&mut hdr).is_err() {
            return;
        }
        let op = hdr[0];
        let topic_len = u16::from_le_bytes(hdr[1..3].try_into().unwrap()) as usize;
        let mut topic = vec![0u8; topic_len];
        if reader.read_exact(&mut topic).is_err() {
            return;
        }
        let Ok(topic) = String::from_utf8(topic) else { return };
        let mut len4 = [0u8; 4];
        if reader.read_exact(&mut len4).is_err() {
            return;
        }
        let len = u32::from_le_bytes(len4) as usize;
        let mut payload = vec![0u8; len];
        if reader.read_exact(&mut payload).is_err() {
            return;
        }
        match op {
            1 => {
                // PUBLISH
                {
                    let mut topics = state.topics.lock().unwrap();
                    topics
                        .entry(topic)
                        .or_default()
                        .records
                        .push(Arc::new(payload));
                    state.appended.notify_all();
                }
                if writer.write_all(&[0u8]).is_err() {
                    return;
                }
            }
            2 => {
                // FETCH offset timeout
                if payload.len() != 12 {
                    let _ = writer.write_all(&[2u8, 0, 0, 0, 0]);
                    return;
                }
                let offset =
                    u64::from_le_bytes(payload[0..8].try_into().unwrap()) as usize;
                let timeout_ms = u32::from_le_bytes(payload[8..12].try_into().unwrap());
                let deadline = Instant::now() + Duration::from_millis(timeout_ms as u64);
                let record: Option<Arc<Vec<u8>>> = {
                    let mut topics = state.topics.lock().unwrap();
                    loop {
                        if let Some(r) = topics
                            .get(&topic)
                            .and_then(|t| t.records.get(offset))
                        {
                            break Some(r.clone());
                        }
                        if stop.load(Ordering::Relaxed) || Instant::now() >= deadline {
                            break None;
                        }
                        let wait = (deadline - Instant::now()).min(Duration::from_millis(50));
                        topics = state.appended.wait_timeout(topics, wait).unwrap().0;
                    }
                };
                let ok = match &record {
                    Some(r) => {
                        let mut resp = Vec::with_capacity(5 + r.len());
                        resp.push(0u8);
                        resp.extend_from_slice(&(r.len() as u32).to_le_bytes());
                        resp.extend_from_slice(r);
                        writer.write_all(&resp).is_ok()
                    }
                    None => writer.write_all(&[1u8, 0, 0, 0, 0]).is_ok(),
                };
                if !ok {
                    return;
                }
            }
            _ => return,
        }
    }
}

// ------------------------------------------------------------------ Client

/// Producer/consumer client. Measures where its time goes, so the bench
/// can report the paper's copy/serialize split.
pub struct BusClient {
    conn: Mutex<(BufReader<TcpStream>, TcpStream)>,
    stage: DeviceStage,
    /// Cumulative seconds: (device copy, serialize, network).
    pub time_copy: Mutex<f64>,
    pub time_serialize: Mutex<f64>,
    pub time_network: Mutex<f64>,
}

impl BusClient {
    pub fn connect(addr: SocketAddr, stage: DeviceStage) -> anyhow::Result<BusClient> {
        let conn = TcpStream::connect(addr)?;
        conn.set_nodelay(true)?;
        let writer = conn.try_clone()?;
        Ok(BusClient {
            conn: Mutex::new((BufReader::new(conn), writer)),
            stage,
            time_copy: Mutex::new(0.0),
            time_serialize: Mutex::new(0.0),
            time_network: Mutex::new(0.0),
        })
    }

    /// Produce one tensor: device→host copy, serialize, publish.
    pub fn publish_tensor(&self, topic: &str, t: &Tensor) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let staged = self.stage.to_host(t);
        let t1 = Instant::now();
        // Serialize: tensor header + the staged payload (a second pass
        // over the bytes, as pickle/avro would do).
        let header_only =
            Tensor::from_bytes(t.dtype(), t.shape(), staged).expect("restage");
        let mut record = Vec::with_capacity(64 + t.byte_len());
        write_tensor(&mut record, &header_only)?;
        let t2 = Instant::now();
        {
            let mut conn = self.conn.lock().unwrap();
            let mut req = Vec::with_capacity(7 + topic.len() + record.len());
            req.push(1u8);
            req.extend_from_slice(&(topic.len() as u16).to_le_bytes());
            req.extend_from_slice(topic.as_bytes());
            req.extend_from_slice(&(record.len() as u32).to_le_bytes());
            req.extend_from_slice(&record);
            conn.1.write_all(&req)?;
            let mut status = [0u8; 1];
            conn.0.read_exact(&mut status)?;
            anyhow::ensure!(status[0] == 0, "publish failed");
        }
        let t3 = Instant::now();
        *self.time_copy.lock().unwrap() += (t1 - t0).as_secs_f64();
        *self.time_serialize.lock().unwrap() += (t2 - t1).as_secs_f64();
        *self.time_network.lock().unwrap() += (t3 - t2).as_secs_f64();
        Ok(())
    }

    /// Consume one tensor: fetch, deserialize, host→device copy.
    pub fn fetch_tensor(
        &self,
        topic: &str,
        offset: u64,
        timeout: Duration,
    ) -> anyhow::Result<Option<Tensor>> {
        let t0 = Instant::now();
        let record = {
            let mut conn = self.conn.lock().unwrap();
            let mut req = Vec::with_capacity(19 + topic.len());
            req.push(2u8);
            req.extend_from_slice(&(topic.len() as u16).to_le_bytes());
            req.extend_from_slice(topic.as_bytes());
            req.extend_from_slice(&12u32.to_le_bytes());
            req.extend_from_slice(&offset.to_le_bytes());
            req.extend_from_slice(&(timeout.as_millis() as u32).to_le_bytes());
            conn.1.write_all(&req)?;
            let mut status = [0u8; 1];
            conn.0.read_exact(&mut status)?;
            let mut len4 = [0u8; 4];
            conn.0.read_exact(&mut len4)?;
            let len = u32::from_le_bytes(len4) as usize;
            let mut payload = vec![0u8; len];
            conn.0.read_exact(&mut payload)?;
            match status[0] {
                0 => payload,
                1 => return Ok(None), // timeout
                _ => anyhow::bail!("fetch error"),
            }
        };
        let t1 = Instant::now();
        let tensor = read_tensor(&mut record.as_slice())?;
        let t2 = Instant::now();
        let on_device = self.stage.to_device(tensor.bytes());
        let tensor = Tensor::from_bytes(tensor.dtype(), tensor.shape(), on_device)?;
        let t3 = Instant::now();
        *self.time_network.lock().unwrap() += (t1 - t0).as_secs_f64();
        *self.time_serialize.lock().unwrap() += (t2 - t1).as_secs_f64();
        *self.time_copy.lock().unwrap() += (t3 - t2).as_secs_f64();
        Ok(Some(tensor))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn publish_fetch_roundtrip() {
        let broker = Broker::start().unwrap();
        let producer = BusClient::connect(broker.addr(), DeviceStage::new(1e12)).unwrap();
        let consumer = BusClient::connect(broker.addr(), DeviceStage::new(1e12)).unwrap();
        let mut rng = Rng::new(4);
        let t = Tensor::rand_f32(&[100], &mut rng);
        producer.publish_tensor("acts", &t).unwrap();
        let got = consumer
            .fetch_tensor("acts", 0, Duration::from_secs(2))
            .unwrap()
            .unwrap();
        assert_eq!(got.checksum(), t.checksum());
        assert_eq!(broker.topic_len("acts"), 1);
    }

    #[test]
    fn fetch_blocks_until_publish() {
        let broker = Broker::start().unwrap();
        let addr = broker.addr();
        let consumer = BusClient::connect(addr, DeviceStage::new(1e12)).unwrap();
        let producer_thread = std::thread::spawn(move || {
            let producer = BusClient::connect(addr, DeviceStage::new(1e12)).unwrap();
            std::thread::sleep(Duration::from_millis(50));
            producer
                .publish_tensor("later", &Tensor::from_f32(&[1], &[3.0]))
                .unwrap();
        });
        let t0 = Instant::now();
        let got = consumer
            .fetch_tensor("later", 0, Duration::from_secs(5))
            .unwrap()
            .unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(40));
        assert_eq!(got.as_f32(), &[3.0]);
        producer_thread.join().unwrap();
    }

    #[test]
    fn fetch_timeout_returns_none() {
        let broker = Broker::start().unwrap();
        let consumer = BusClient::connect(broker.addr(), DeviceStage::new(1e12)).unwrap();
        let got = consumer
            .fetch_tensor("empty", 0, Duration::from_millis(60))
            .unwrap();
        assert!(got.is_none());
    }

    #[test]
    fn offsets_replay_the_log() {
        let broker = Broker::start().unwrap();
        let producer = BusClient::connect(broker.addr(), DeviceStage::new(1e12)).unwrap();
        for i in 0..3 {
            producer
                .publish_tensor("log", &Tensor::from_f32(&[1], &[i as f32]))
                .unwrap();
        }
        let consumer = BusClient::connect(broker.addr(), DeviceStage::new(1e12)).unwrap();
        for i in 0..3 {
            let t = consumer
                .fetch_tensor("log", i, Duration::from_secs(1))
                .unwrap()
                .unwrap();
            assert_eq!(t.as_f32(), &[i as f32]);
        }
    }

    #[test]
    fn device_stage_throttles() {
        let stage = DeviceStage::new(100.0e6); // 100 MB/s
        let t = Tensor::zeros(crate::tensor::DType::F32, &[500_000]); // 2 MB
        let t0 = Instant::now();
        let _ = stage.to_host(&t);
        assert!(t0.elapsed() >= Duration::from_millis(15), "pacing applied");
    }

    #[test]
    fn time_accounting_accumulates() {
        let broker = Broker::start().unwrap();
        let producer = BusClient::connect(broker.addr(), DeviceStage::new(1e9)).unwrap();
        let mut rng = Rng::new(5);
        let t = Tensor::rand_f32(&[50_000], &mut rng);
        producer.publish_tensor("t", &t).unwrap();
        assert!(*producer.time_copy.lock().unwrap() > 0.0);
        assert!(*producer.time_serialize.lock().unwrap() > 0.0);
        assert!(*producer.time_network.lock().unwrap() > 0.0);
    }
}
