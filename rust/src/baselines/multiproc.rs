//! The MultiProcessing ("MP") baseline — the alternative MultiWorld
//! architecture the paper evaluates and rejects (§4.3): instead of one
//! process holding many worlds, a *main* process delegates each world to
//! a dedicated **subprocess**, moving every tensor across the process
//! boundary through pipe IPC (serialize → pipe write → pipe read →
//! deserialize) before it ever reaches the CCL.
//!
//! The extra IPC hop is exactly why MP loses at small tensor sizes in
//! Fig. 6 (and only approaches MW/SW at 4 MB on the bandwidth-limited
//! host-to-host path).
//!
//! Implementation: [`MpEndpoint::spawn`] launches `multiworld mp-proxy`,
//! a child that joins the world as the given rank and shuttles framed
//! tensors between its stdin/stdout and the CCL. The main process talks
//! to the child exclusively through those pipes.

use crate::tensor::{read_tensor, write_tensor, Tensor};
use std::io::{BufReader, BufWriter, Write};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};

/// Locate the `multiworld` binary for spawning proxies from tests and
/// benches (their `current_exe` is the test harness, not our CLI).
pub fn multiworld_bin() -> anyhow::Result<std::path::PathBuf> {
    if let Ok(p) = std::env::var("MW_BIN") {
        return Ok(p.into());
    }
    let exe = std::env::current_exe()?;
    // target/{debug,release}/deps/<test> -> target/{debug,release}/multiworld
    for dir in [exe.parent(), exe.parent().and_then(|p| p.parent())]
        .into_iter()
        .flatten()
    {
        let cand = dir.join("multiworld");
        if cand.exists() {
            return Ok(cand);
        }
    }
    anyhow::bail!(
        "multiworld binary not found near {} (build it or set MW_BIN)",
        exe.display()
    )
}

/// Main-process handle to one world's proxy subprocess.
pub struct MpEndpoint {
    child: Child,
    stdin: Option<BufWriter<ChildStdin>>,
    stdout: BufReader<ChildStdout>,
}

impl MpEndpoint {
    /// Spawn the proxy: it joins `world` as `rank` (of 2) over the given
    /// transport, with the per-world store on `store_port`.
    pub fn spawn(
        world: &str,
        rank: usize,
        store_port: u16,
        transport: &str,
    ) -> anyhow::Result<MpEndpoint> {
        let bin = multiworld_bin()?;
        let mut child = Command::new(bin)
            .arg("mp-proxy")
            .arg("--world")
            .arg(world)
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--store-port")
            .arg(store_port.to_string())
            .arg("--transport")
            .arg(transport)
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()?;
        let stdin = Some(BufWriter::new(child.stdin.take().expect("proxy stdin")));
        let stdout = BufReader::new(child.stdout.take().expect("proxy stdout"));
        Ok(MpEndpoint { child, stdin, stdout })
    }

    /// Ship a tensor to the peer: serialize across the IPC pipe; the
    /// proxy forwards it through the CCL.
    pub fn send_tensor(&mut self, t: &Tensor) -> anyhow::Result<()> {
        let stdin = self
            .stdin
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("endpoint already shut down"))?;
        write_tensor(stdin, t)?;
        stdin.flush()?;
        Ok(())
    }

    /// Receive a tensor the proxy pulled from the CCL (deserialized off
    /// the IPC pipe).
    pub fn recv_tensor(&mut self) -> anyhow::Result<Tensor> {
        read_tensor(&mut self.stdout)
    }

    /// Close stdin (EOF → proxy drains and exits) and reap.
    pub fn shutdown(mut self) -> anyhow::Result<()> {
        drop(self.stdin.take());
        let _ = self.child.wait()?;
        Ok(())
    }

    /// Hard-kill the proxy (failure injection).
    pub fn kill(mut self) -> anyhow::Result<()> {
        self.child.kill()?;
        let _ = self.child.wait();
        Ok(())
    }
}

impl Drop for MpEndpoint {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// The proxy-side loop (runs inside `multiworld mp-proxy`): stdin →
/// world.send, world.recv → stdout, independent tag streams, until
/// stdin EOF or a CCL error.
pub fn run_proxy(
    world_name: &str,
    rank: usize,
    store_port: u16,
    transport: &str,
) -> anyhow::Result<()> {
    use crate::mwccl::{World, WorldOptions};
    let opts = match transport {
        "tcp" => WorldOptions::tcp(),
        "shm" => WorldOptions::shm(),
        other => anyhow::bail!("unknown transport {other}"),
    };
    let addr: std::net::SocketAddr = format!("127.0.0.1:{store_port}").parse()?;
    let world = World::init(world_name, rank, 2, addr, opts)
        .map_err(|e| anyhow::anyhow!("proxy init: {e}"))?;
    let peer = 1 - rank;

    // Downlink: CCL → stdout.
    let w2 = world.clone();
    let down = std::thread::Builder::new()
        .name("mp-proxy-down".into())
        .spawn(move || -> anyhow::Result<()> {
            let stdout = std::io::stdout();
            let mut out = BufWriter::new(stdout.lock());
            let mut tag = 0u64;
            loop {
                match w2.recv(peer, tag) {
                    Ok(t) => {
                        write_tensor(&mut out, &t)?;
                        out.flush()?;
                        tag += 1;
                    }
                    Err(_) => return Ok(()), // world gone — exit quietly
                }
            }
        })?;

    // Uplink: stdin → CCL.
    let stdin = std::io::stdin();
    let mut input = BufReader::new(stdin.lock());
    let mut tag = 0u64;
    loop {
        match read_tensor(&mut input) {
            Ok(t) => {
                world
                    .send(t, peer, tag)
                    .map_err(|e| anyhow::anyhow!("proxy send: {e}"))?;
                tag += 1;
            }
            Err(_) => break, // EOF from the main process
        }
    }
    // The downlink thread holds a World clone, so a plain drop would not
    // tear the links down — abort explicitly to unblock its recv.
    world.abort("proxy stdin closed");
    drop(world);
    let _ = down.join();
    Ok(())
}
