//! proptest-lite: a tiny property-based testing harness.
//!
//! The offline registry has no `proptest`/`quickcheck`, so we carry the
//! 10% of the idea we need: run a property over a few hundred generated
//! cases from a deterministic seed, and on failure *shrink* the input by
//! re-running the property over progressively smaller candidates before
//! reporting. Generators are plain closures over [`crate::util::prng::Rng`]
//! plus a `Shrink` hook.

use crate::util::prng::Rng;

/// Number of cases per property (override with `MW_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("MW_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(192)
}

/// A value generator with an optional shrinker.
pub struct Gen<T> {
    pub gen: Box<dyn Fn(&mut Rng) -> T>,
    pub shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(gen: impl Fn(&mut Rng) -> T + 'static) -> Self {
        Gen { gen: Box::new(gen), shrink: Box::new(|_| Vec::new()) }
    }

    pub fn with_shrink(mut self, shrink: impl Fn(&T) -> Vec<T> + 'static) -> Self {
        self.shrink = Box::new(shrink);
        self
    }

    /// Map the generated value (shrinking is lost across map; fine for
    /// derived small types).
    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + 'static) -> Gen<U> {
        let g = self.gen;
        Gen::new(move |r| f(g(r)))
    }
}

/// usize in `[lo, hi]` with geometric shrink toward `lo`: candidates jump
/// half the remaining distance first, so shrinking converges to the
/// boundary of the failing region in O(log range) passes.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    Gen::new(move |r: &mut Rng| r.range(lo, hi)).with_shrink(move |&v| {
        let mut out = Vec::new();
        if v > lo {
            out.push(lo);
            let mut delta = (v - lo) / 2;
            while delta > 0 {
                out.push(v - delta);
                delta /= 2;
            }
            out.dedup();
        }
        out
    })
}

/// Vec of f32 in [-1,1) with length in `[min_len, max_len]`; shrinks by
/// halving the length and zeroing elements.
pub fn vec_f32(min_len: usize, max_len: usize) -> Gen<Vec<f32>> {
    Gen::new(move |r: &mut Rng| {
        let n = r.range(min_len, max_len);
        let mut v = vec![0.0f32; n];
        r.fill_f32(&mut v);
        v
    })
    .with_shrink(move |v: &Vec<f32>| {
        let mut out = Vec::new();
        if v.len() > min_len {
            let half = (v.len() / 2).max(min_len);
            out.push(v[..half].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        if v.iter().any(|&x| x != 0.0) {
            out.push(vec![0.0; v.len()]);
        }
        out
    })
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` generated inputs; shrink on failure; panic
/// with the minimal counterexample. The seed is fixed per property name
/// so failures reproduce.
pub fn check<T: Clone + std::fmt::Debug + 'static>(
    name: &str,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> PropResult,
) {
    let seed = name.bytes().fold(0xC0FFEEu64, |h, b| {
        h.wrapping_mul(0x100000001B3).wrapping_add(b as u64)
    });
    let mut rng = Rng::new(seed);
    let cases = default_cases();
    for case in 0..cases {
        let input = (gen.gen)(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(gen, &prop, input, msg);
            panic!(
                "property '{name}' failed (case {case}/{cases}):\n  input: {min_input:?}\n  error: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T: Clone + std::fmt::Debug>(
    gen: &Gen<T>,
    prop: &impl Fn(&T) -> PropResult,
    mut current: T,
    mut msg: String,
) -> (T, String) {
    // Bounded shrink passes to avoid infinite loops with cyclic shrinkers.
    for _ in 0..64 {
        let mut advanced = false;
        for cand in (gen.shrink)(&current) {
            if let Err(m) = prop(&cand) {
                current = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (current, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", &vec_f32(0, 32), |v| {
            let fwd: f32 = v.iter().sum();
            let rev: f32 = v.iter().rev().sum();
            // Float addition is not associative, but reversal of <=32
            // small values stays within a loose tolerance.
            if (fwd - rev).abs() < 1e-3 {
                Ok(())
            } else {
                Err(format!("{fwd} vs {rev}"))
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let r = std::panic::catch_unwind(|| {
            check("always-small", &usize_in(0, 1000), |&v| {
                if v < 500 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            });
        });
        let msg = match r {
            Ok(_) => panic!("property should have failed"),
            Err(e) => *e.downcast::<String>().unwrap(),
        };
        // Shrinker should walk 500..=1000 down to exactly 500.
        assert!(msg.contains("input: 500"), "unshrunk: {msg}");
    }

    #[test]
    fn deterministic_by_name() {
        let mut seen = Vec::new();
        for _ in 0..2 {
            let collected = std::cell::RefCell::new(Vec::new());
            check("det", &usize_in(0, 99), |&v| {
                collected.borrow_mut().push(v);
                Ok(())
            });
            seen.push(collected.into_inner());
        }
        assert_eq!(seen[0], seen[1]);
    }
}
