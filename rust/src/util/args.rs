//! A small declarative CLI argument parser (clap is not available
//! offline). Supports subcommands, `--flag`, `--key value`,
//! `--key=value`, positional arguments, defaults and generated help.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
    pub required: bool,
}

/// Specification of a command (or subcommand).
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    opts: Vec<OptSpec>,
    positionals: Vec<(&'static str, &'static str)>,
    subs: Vec<Command>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, ..Default::default() }
    }

    /// `--key <value>` option with an optional default.
    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec { name, help, default, is_flag: false, required: false });
        self
    }

    /// `--key <value>` option that must be present.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false, required: true });
        self
    }

    /// Boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true, required: false });
        self
    }

    /// Positional argument (all required, ordered).
    pub fn pos(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    pub fn sub(mut self, cmd: Command) -> Self {
        self.subs.push(cmd);
        self
    }

    /// Render `--help` text.
    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        let _ = write!(s, "usage: {}", self.name);
        if !self.subs.is_empty() {
            let _ = write!(s, " <command>");
        }
        for (p, _) in &self.positionals {
            let _ = write!(s, " <{p}>");
        }
        if !self.opts.is_empty() {
            let _ = write!(s, " [options]");
        }
        let _ = writeln!(s);
        if !self.subs.is_empty() {
            let _ = writeln!(s, "\ncommands:");
            for c in &self.subs {
                let _ = writeln!(s, "  {:<24} {}", c.name, c.about);
            }
        }
        if !self.positionals.is_empty() {
            let _ = writeln!(s, "\narguments:");
            for (p, h) in &self.positionals {
                let _ = writeln!(s, "  {:<24} {}", format!("<{p}>"), h);
            }
        }
        if !self.opts.is_empty() {
            let _ = writeln!(s, "\noptions:");
            for o in &self.opts {
                let left = if o.is_flag {
                    format!("--{}", o.name)
                } else {
                    format!("--{} <v>", o.name)
                };
                let default = match o.default {
                    Some(d) => format!(" [default: {d}]"),
                    None if o.required => " [required]".to_string(),
                    None => String::new(),
                };
                let _ = writeln!(s, "  {:<24} {}{}", left, o.help, default);
            }
        }
        s
    }

    /// Parse argv (excluding the program name). Returns the matched
    /// subcommand chain and values, or a printable error/help string.
    pub fn parse(&self, argv: &[String]) -> Result<Matches, String> {
        let mut m = Matches {
            command: self.name.to_string(),
            values: BTreeMap::new(),
            flags: Vec::new(),
            positionals: Vec::new(),
            sub: None,
        };
        for o in &self.opts {
            if let Some(d) = o.default {
                m.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.help_text());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.help_text()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    m.flags.push(key.to_string());
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option --{key} needs a value"))?
                        }
                    };
                    m.values.insert(key.to_string(), v);
                }
            } else if !self.subs.is_empty() && m.sub.is_none() && m.positionals.is_empty() {
                let sub = self
                    .subs
                    .iter()
                    .find(|c| c.name == a.as_str())
                    .ok_or_else(|| format!("unknown command '{a}'\n\n{}", self.help_text()))?;
                let rest = argv[i + 1..].to_vec();
                let sub_matches = sub.parse(&rest)?;
                m.sub = Some(Box::new(sub_matches));
                break;
            } else {
                m.positionals.push(a.clone());
            }
            i += 1;
        }
        if m.sub.is_none() {
            for o in &self.opts {
                if o.required && !m.values.contains_key(o.name) {
                    return Err(format!("missing required --{}\n\n{}", o.name, self.help_text()));
                }
            }
            if !self.subs.is_empty() {
                return Err(format!("missing command\n\n{}", self.help_text()));
            }
            if m.positionals.len() < self.positionals.len() {
                return Err(format!(
                    "missing argument <{}>\n\n{}",
                    self.positionals[m.positionals.len()].0,
                    self.help_text()
                ));
            }
        }
        Ok(m)
    }
}

/// Parse results.
#[derive(Debug, Clone)]
pub struct Matches {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
    pub sub: Option<Box<Matches>>,
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .ok_or_else(|| format!("--{name} missing"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn u64(&self, name: &str) -> Result<u64, String> {
        self.get(name)
            .ok_or_else(|| format!("--{name} missing"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .ok_or_else(|| format!("--{name} missing"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    fn cli() -> Command {
        Command::new("multiworld", "test cli")
            .sub(
                Command::new("worker", "run a worker")
                    .req("rank", "rank in world")
                    .opt("size", "tensor size", Some("1024"))
                    .flag("verbose", "chatty"),
            )
            .sub(Command::new("launch", "launch topology").pos("config", "path"))
    }

    #[test]
    fn parses_subcommand_options() {
        let m = cli().parse(&argv("worker --rank 3 --size=4096 --verbose")).unwrap();
        let w = m.sub.unwrap();
        assert_eq!(w.command, "worker");
        assert_eq!(w.usize("rank").unwrap(), 3);
        assert_eq!(w.usize("size").unwrap(), 4096);
        assert!(w.flag("verbose"));
    }

    #[test]
    fn default_applies() {
        let m = cli().parse(&argv("worker --rank 0")).unwrap();
        assert_eq!(m.sub.unwrap().usize("size").unwrap(), 1024);
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse(&argv("worker")).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(&argv("worker --rank 0 --nope 1")).is_err());
    }

    #[test]
    fn positional_required() {
        assert!(cli().parse(&argv("launch")).is_err());
        let m = cli().parse(&argv("launch topo.json")).unwrap();
        assert_eq!(m.sub.unwrap().positionals, vec!["topo.json"]);
    }

    #[test]
    fn help_is_error_path() {
        let e = cli().parse(&argv("--help")).unwrap_err();
        assert!(e.contains("commands:"));
    }
}
