//! Small self-contained utilities: deterministic PRNG, wall/mono clocks,
//! a minimal JSON value + parser, a clap-free argument parser, a
//! proptest-lite property harness, and shared helpers.

pub mod args;
pub mod json;
pub mod prng;
pub mod prop;
pub mod time;

use std::net::TcpListener;

/// Pick an unused localhost TCP port by binding port 0 and dropping the
/// listener. Races are possible but vanishingly rare in tests.
pub fn free_port() -> u16 {
    let l = TcpListener::bind(("127.0.0.1", 0)).expect("bind :0");
    l.local_addr().unwrap().port()
}

/// Format a byte count with binary units ("4.0 KiB", "3.2 GiB").
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.1} {}", v, UNITS[u])
    }
}

/// Format a throughput in bytes/sec using decimal units matching the
/// paper's figures (MB/s, GB/s).
pub fn fmt_rate(bytes_per_sec: f64) -> String {
    if bytes_per_sec >= 1e9 {
        format!("{:.2} GB/s", bytes_per_sec / 1e9)
    } else if bytes_per_sec >= 1e6 {
        format!("{:.2} MB/s", bytes_per_sec / 1e6)
    } else if bytes_per_sec >= 1e3 {
        format!("{:.2} KB/s", bytes_per_sec / 1e3)
    } else {
        format!("{:.2} B/s", bytes_per_sec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn free_port_is_bindable() {
        let p = free_port();
        assert!(p > 0);
        // Port should be immediately re-bindable.
        TcpListener::bind(("127.0.0.1", p)).unwrap();
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(12), "12 B");
        assert_eq!(fmt_bytes(4096), "4.0 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn fmt_rate_units() {
        assert_eq!(fmt_rate(147.0e6), "147.00 MB/s");
        assert_eq!(fmt_rate(15.9e9), "15.90 GB/s");
        assert_eq!(fmt_rate(12.0), "12.00 B/s");
    }
}
