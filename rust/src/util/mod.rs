//! Small self-contained utilities: deterministic PRNG, wall/mono clocks,
//! a minimal JSON value + parser, a clap-free argument parser, a
//! proptest-lite property harness, and shared helpers.

pub mod args;
pub mod json;
pub mod prng;
pub mod prop;
pub mod time;

use std::net::TcpListener;

/// Pick an unused localhost TCP port by binding port 0 and dropping the
/// listener. Races are possible but vanishingly rare in tests.
pub fn free_port() -> u16 {
    let l = TcpListener::bind(("127.0.0.1", 0)).expect("bind :0");
    l.local_addr().unwrap().port()
}

/// Blocking accept with a deadline, no poll loop: `SO_RCVTIMEO` on the
/// listener makes the kernel wake us on connection-or-timeout, so a
/// rank parked in rendezvous accept burns zero CPU (the 2 ms
/// sleep-poll this replaced burned a wakeup per tick per rank).
///
/// The accepted stream has its inherited receive timeout cleared —
/// Linux copies the listener's `SO_RCVTIMEO` onto accepted sockets,
/// which would otherwise poison later blocking reads.
pub fn accept_deadline(
    listener: &TcpListener,
    deadline: std::time::Instant,
) -> std::io::Result<std::net::TcpStream> {
    use std::os::unix::io::AsRawFd;
    listener.set_nonblocking(false)?;
    loop {
        let now = std::time::Instant::now();
        if now >= deadline {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "accept deadline passed",
            ));
        }
        // Zero means "block forever" to the kernel: clamp up to 1 ms.
        let left = (deadline - now).max(std::time::Duration::from_millis(1));
        let tv = libc::timeval {
            tv_sec: left.as_secs().min(i64::MAX as u64) as libc::time_t,
            tv_usec: left.subsec_micros() as libc::suseconds_t,
        };
        let rc = unsafe {
            libc::setsockopt(
                listener.as_raw_fd(),
                libc::SOL_SOCKET,
                libc::SO_RCVTIMEO,
                &tv as *const libc::timeval as *const libc::c_void,
                std::mem::size_of::<libc::timeval>() as libc::socklen_t,
            )
        };
        if rc != 0 {
            return Err(std::io::Error::last_os_error());
        }
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_read_timeout(None)?;
                return Ok(stream);
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Format a byte count with binary units ("4.0 KiB", "3.2 GiB").
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.1} {}", v, UNITS[u])
    }
}

/// Format a throughput in bytes/sec using decimal units matching the
/// paper's figures (MB/s, GB/s).
pub fn fmt_rate(bytes_per_sec: f64) -> String {
    if bytes_per_sec >= 1e9 {
        format!("{:.2} GB/s", bytes_per_sec / 1e9)
    } else if bytes_per_sec >= 1e6 {
        format!("{:.2} MB/s", bytes_per_sec / 1e6)
    } else if bytes_per_sec >= 1e3 {
        format!("{:.2} KB/s", bytes_per_sec / 1e3)
    } else {
        format!("{:.2} B/s", bytes_per_sec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn free_port_is_bindable() {
        let p = free_port();
        assert!(p > 0);
        // Port should be immediately re-bindable.
        TcpListener::bind(("127.0.0.1", p)).unwrap();
    }

    #[test]
    fn accept_deadline_times_out_then_accepts() {
        let l = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let t0 = std::time::Instant::now();
        let err = accept_deadline(&l, t0 + std::time::Duration::from_millis(50)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(40));
        let addr = l.local_addr().unwrap();
        let dialer = std::thread::spawn(move || std::net::TcpStream::connect(addr).unwrap());
        let s = accept_deadline(&l, std::time::Instant::now() + std::time::Duration::from_secs(2))
            .unwrap();
        assert!(s.read_timeout().unwrap().is_none(), "inherited timeout cleared");
        dialer.join().unwrap();
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(12), "12 B");
        assert_eq!(fmt_bytes(4096), "4.0 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn fmt_rate_units() {
        assert_eq!(fmt_rate(147.0e6), "147.00 MB/s");
        assert_eq!(fmt_rate(15.9e9), "15.90 GB/s");
        assert_eq!(fmt_rate(12.0), "12.00 B/s");
    }
}
