//! A minimal JSON value, parser and writer.
//!
//! `serde`/`serde_json` are not in the offline registry, and the repo
//! needs JSON in exactly three places: the AOT `model.json` manifest
//! written by `python/compile/aot.py`, experiment result dumps, and the
//! launcher's topology files. This module implements the subset of JSON
//! we emit and consume (objects, arrays, strings with escapes, f64
//! numbers, bools, null) with positions in parse errors.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are f64 (the manifest only carries shapes and
/// sizes, all exactly representable).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]` access that tolerates missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Index into an array.
    pub fn at(&self, idx: usize) -> Option<&Json> {
        self.as_arr().and_then(|v| v.get(idx))
    }

    /// Parse from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Convenience builders.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

impl fmt::Display for Json {
    /// Compact serialization (valid JSON).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"
        {
          "model": "tiny-transformer",
          "stages": [
            {"name": "stage_0", "hlo": "artifacts/stage_0.hlo.txt",
             "in_shape": [8, 16, 64], "out_shape": [8, 16, 64]},
            {"name": "stage_1", "hlo": "artifacts/stage_1.hlo.txt",
             "in_shape": [8, 16, 64], "out_shape": [8, 16, 32]}
          ],
          "d_model": 64, "vocab": 256, "causal": true, "note": null
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "tiny-transformer");
        assert_eq!(j.get("d_model").unwrap().as_usize().unwrap(), 64);
        assert_eq!(j.get("causal").unwrap().as_bool().unwrap(), true);
        assert_eq!(j.get("note").unwrap(), &Json::Null);
        let stages = j.get("stages").unwrap().as_arr().unwrap();
        assert_eq!(stages.len(), 2);
        assert_eq!(
            stages[1].get("in_shape").unwrap().at(2).unwrap().as_usize().unwrap(),
            64
        );
    }

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::num(1.5)),
            ("b", Json::arr(vec![Json::Bool(true), Json::Null, Json::str("x\"y\n")])),
            ("c", Json::num(-3.0)),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,2,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-2.5e2").unwrap().as_f64().unwrap(), -250.0);
        assert_eq!(Json::parse("0").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(Json::parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse("\"caf\\u00e9 — ok\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "café — ok");
    }
}
