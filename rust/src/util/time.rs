//! Clock helpers: a shared monotonic epoch for experiment timelines and a
//! controllable clock for deterministic tests.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Seconds since the process-wide experiment epoch. All timeline plots
/// (Figs 4 and 5) stamp events with this so multiple threads agree on t=0.
pub fn since_epoch() -> f64 {
    epoch().elapsed().as_secs_f64()
}

/// The experiment epoch — first call wins.
pub fn epoch() -> Instant {
    use once_cell::sync::Lazy;
    static EPOCH: Lazy<Instant> = Lazy::new(Instant::now);
    *EPOCH
}

/// Unix wall-clock in milliseconds (heartbeat stamps that cross
/// processes go through the store as wall time).
pub fn unix_millis() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// A stopwatch with lap support for coarse phase timing.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now }
    }

    /// Time since construction.
    pub fn total(&self) -> Duration {
        self.start.elapsed()
    }

    /// Time since the previous `lap()` (or construction).
    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        d
    }
}

/// A virtual clock the watchdog tests can drive manually. Real code uses
/// [`Clock::system`]; tests use [`Clock::manual`] and call
/// [`Clock::advance`] to simulate missed heartbeats without sleeping.
#[derive(Clone, Debug)]
pub struct Clock {
    inner: ClockInner,
}

#[derive(Clone, Debug)]
enum ClockInner {
    System,
    Manual(Arc<AtomicU64>), // millis
}

impl Clock {
    pub fn system() -> Self {
        Clock { inner: ClockInner::System }
    }

    pub fn manual() -> Self {
        Clock {
            inner: ClockInner::Manual(Arc::new(AtomicU64::new(0))),
        }
    }

    /// Current time in milliseconds (wall for system, virtual otherwise).
    pub fn now_millis(&self) -> u64 {
        match &self.inner {
            ClockInner::System => unix_millis(),
            ClockInner::Manual(t) => t.load(Ordering::SeqCst),
        }
    }

    /// Advance a manual clock; panics on a system clock.
    pub fn advance(&self, d: Duration) {
        match &self.inner {
            ClockInner::System => panic!("cannot advance the system clock"),
            ClockInner::Manual(t) => {
                t.fetch_add(d.as_millis() as u64, Ordering::SeqCst);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_laps_increase() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(5));
        let l1 = sw.lap();
        assert!(l1 >= Duration::from_millis(4));
        let l2 = sw.lap();
        assert!(l2 < l1);
        assert!(sw.total() >= l1);
    }

    #[test]
    fn manual_clock_advances() {
        let c = Clock::manual();
        assert_eq!(c.now_millis(), 0);
        c.advance(Duration::from_millis(1500));
        assert_eq!(c.now_millis(), 1500);
        let c2 = c.clone();
        c2.advance(Duration::from_millis(500));
        assert_eq!(c.now_millis(), 2000, "clones share time");
    }

    #[test]
    fn epoch_is_monotone() {
        let a = since_epoch();
        let b = since_epoch();
        assert!(b >= a);
    }
}
