//! Deterministic pseudo-random numbers (SplitMix64 + xoshiro256**).
//!
//! No `rand` crate exists in the offline registry, so we carry our own
//! generator. xoshiro256** is the standard small-state generator with
//! good statistical quality; SplitMix64 seeds it from a single u64 (the
//! construction recommended by the xoshiro authors).

/// SplitMix64 step — used for seeding and as a cheap standalone stream.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator. `Clone` so property tests can fork streams.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // All-zero state is a fixed point; splitmix of any seed avoids it,
        // but be defensive.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Rng { s }
    }

    /// Seed from the monotonic clock — for non-reproducible workloads.
    pub fn from_time() -> Self {
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5EED);
        Self::new(t ^ std::process::id() as u64)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`, 53-bit precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (Lemire's method, no modulo bias).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponentially distributed sample with the given rate (events/sec);
    /// used for Poisson request arrivals in the workload generator.
    #[inline]
    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        // Avoid ln(0).
        let u = 1.0 - self.next_f64();
        -u.ln() / rate
    }

    /// Standard normal via Box–Muller (one value, the pair's twin is
    /// discarded — simplicity over speed; this is not on a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with uniform f32 in [-1, 1) — synthetic tensor data.
    pub fn fill_f32(&mut self, out: &mut [f32]) {
        for v in out {
            *v = self.next_f32() * 2.0 - 1.0;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn exp_mean_close_to_inverse_rate() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| r.exp(4.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_bounds_inclusive() {
        let mut r = Rng::new(13);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 5;
        }
        assert!(lo_seen && hi_seen);
    }
}
