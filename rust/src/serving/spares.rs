//! Host-side weight cache backing the pre-warmed spare pool
//! (`MW_SPARES` / `MW_WEIGHT_CACHE`).
//!
//! The dominant cost of respawn-from-scratch recovery is not the world
//! re-mint (tens of milliseconds) but re-materializing the dead stage's
//! weights — FailSafe's observation, reproduced here: keep the weight
//! bytes resident per *host* so a spare (or a respawned worker on the
//! same host) skips the load entirely. One process is one host in the
//! in-proc launcher, so the cache is process-global ([`host_cache`]);
//! the subprocess launcher gets the same effect from the OS page cache
//! plus the full-runtime pre-warm each `--spare-id` standby runs at
//! startup (see [`crate::launch::ProcessCluster`]).
//!
//! Two read-through maps:
//!
//! * **Stage weights**, keyed `(deployment, stage)`: a deterministic
//!   materialization of `StageSpec::params` parameters (4 bytes each) —
//!   the host→device weight-load stand-in for forward-only workers,
//!   whose synthetic manifests default to `params: 0` (zero cost,
//!   byte-identical to the pre-cache spawn path) until a bench or test
//!   opts into a heavy model. A cold fill costs time proportional to
//!   the stage size; a warm hit is an `Arc` clone.
//! * **HLO artifact bytes**, keyed by path: the disk-read half of a
//!   PJRT stage load, pre-read by spares so promotion compiles from
//!   warm memory.
//!
//! Every lookup lands in `serving.weight_cache.{hits,misses}`. Passing
//! `use_cache: false` (the `MW_WEIGHT_CACHE=0` path) always
//! re-materializes and never stores — recovery still works, it just
//! pays the full load on every spawn.

use crate::config::{ModelManifest, StageSpec};
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// See module docs.
#[derive(Default)]
pub struct WeightCache {
    weights: Mutex<HashMap<(String, usize), Arc<Vec<u8>>>>,
    hlo: Mutex<HashMap<PathBuf, Arc<Vec<u8>>>>,
}

/// The per-host (here: per-process) cache instance.
pub fn host_cache() -> &'static WeightCache {
    static CACHE: Lazy<WeightCache> = Lazy::new(WeightCache::default);
    &CACHE
}

/// Deterministic stand-in for loading a stage's weights from storage:
/// touches every byte, so the cost scales with the stage size the way a
/// real host→device copy does.
fn materialize(spec: &StageSpec) -> Arc<Vec<u8>> {
    let n = (spec.params as usize).saturating_mul(4);
    let mut buf = vec![0u8; n];
    for (i, b) in buf.iter_mut().enumerate() {
        *b = (i as u8) ^ 0x5a;
    }
    Arc::new(buf)
}

impl WeightCache {
    /// The weight bytes for `(deployment, stage)`: a warm hit is an
    /// `Arc` clone, a miss materializes (and, with `use_cache`, stores
    /// for the next spawn on this host).
    pub fn stage_weights(
        &self,
        deployment: &str,
        stage: usize,
        spec: &StageSpec,
        use_cache: bool,
    ) -> Arc<Vec<u8>> {
        let g = crate::metrics::global();
        if use_cache {
            let key = (deployment.to_string(), stage);
            let mut map = self.weights.lock().unwrap();
            if let Some(w) = map.get(&key) {
                g.counter("serving.weight_cache.hits").inc();
                return w.clone();
            }
            g.counter("serving.weight_cache.misses").inc();
            let w = materialize(spec);
            map.insert(key, w.clone());
            w
        } else {
            g.counter("serving.weight_cache.misses").inc();
            materialize(spec)
        }
    }

    /// Read-through cache of an HLO text artifact (the disk half of a
    /// PJRT stage load).
    pub fn hlo_bytes(&self, path: &Path, use_cache: bool) -> anyhow::Result<Arc<Vec<u8>>> {
        let g = crate::metrics::global();
        if use_cache {
            if let Some(b) = self.hlo.lock().unwrap().get(path) {
                g.counter("serving.weight_cache.hits").inc();
                return Ok(b.clone());
            }
        }
        g.counter("serving.weight_cache.misses").inc();
        let bytes = Arc::new(std::fs::read(path)?);
        if use_cache {
            self.hlo.lock().unwrap().insert(path.to_path_buf(), bytes.clone());
        }
        Ok(bytes)
    }

    /// Pre-warm every stage of `manifest` (what a spare does at spawn,
    /// so promotion into *any* stage needs no load).
    pub fn warm(&self, deployment: &str, manifest: &ModelManifest) {
        for (i, spec) in manifest.stages.iter().enumerate() {
            let _ = self.stage_weights(deployment, i, spec, true);
        }
    }

    /// Drop one deployment's weights (cluster teardown in tests — keeps
    /// concurrent test clusters from seeing each other's entries).
    pub fn evict(&self, deployment: &str) {
        self.weights.lock().unwrap().retain(|(d, _), _| d != deployment);
    }

    /// Cached stage-weight entries for `deployment`.
    pub fn cached_stages(&self, deployment: &str) -> usize {
        self.weights.lock().unwrap().keys().filter(|(d, _)| d == deployment).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(params: u64) -> StageSpec {
        StageSpec {
            name: "s".into(),
            hlo: PathBuf::from("s.hlo.txt"),
            in_shape: vec![1, 4],
            out_shape: vec![1, 4],
            in_dtype: crate::tensor::DType::I32,
            out_dtype: crate::tensor::DType::I32,
            params,
        }
    }

    #[test]
    fn warm_hit_returns_same_buffer() {
        let c = WeightCache::default();
        let a = c.stage_weights("wc-t1", 0, &spec(1_000), true);
        let b = c.stage_weights("wc-t1", 0, &spec(1_000), true);
        assert_eq!(a.len(), 4_000);
        assert!(Arc::ptr_eq(&a, &b), "warm hit is the same host buffer");
        assert_eq!(c.cached_stages("wc-t1"), 1);
    }

    #[test]
    fn disabled_cache_rematerializes_and_never_stores() {
        let c = WeightCache::default();
        let a = c.stage_weights("wc-t2", 0, &spec(100), false);
        let b = c.stage_weights("wc-t2", 0, &spec(100), false);
        assert_eq!(a, b, "materialization is deterministic");
        assert!(!Arc::ptr_eq(&a, &b), "no sharing with the cache off");
        assert_eq!(c.cached_stages("wc-t2"), 0);
    }

    #[test]
    fn zero_param_stages_cost_nothing() {
        let c = WeightCache::default();
        assert!(c.stage_weights("wc-t3", 0, &spec(0), true).is_empty());
    }

    #[test]
    fn warm_covers_every_stage_and_evict_forgets() {
        let c = WeightCache::default();
        let m = ModelManifest::synthetic(3, 1, 4, 16);
        c.warm("wc-t4", &m);
        assert_eq!(c.cached_stages("wc-t4"), 3);
        c.evict("wc-t4");
        assert_eq!(c.cached_stages("wc-t4"), 0);
    }

    #[test]
    fn hlo_bytes_reads_through() {
        let dir = std::env::temp_dir().join(format!("mw-hlo-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stage.hlo.txt");
        std::fs::write(&path, b"HloModule m").unwrap();
        let c = WeightCache::default();
        let a = c.hlo_bytes(&path, true).unwrap();
        // A warm hit survives the file disappearing — it is host memory.
        std::fs::remove_file(&path).unwrap();
        let b = c.hlo_bytes(&path, true).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(c.hlo_bytes(&path, false).is_err(), "uncached read goes to disk");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
