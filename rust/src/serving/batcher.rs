//! Deadline-aware admission queue + dynamic batcher: fuse queued
//! requests into model-batch-sized groups, dispatching when the batch
//! fills or a deadline expires (vLLM-style continuous batching
//! simplified to the fixed-batch AOT executable).
//!
//! Admission control:
//!
//! * **Bounded depth** — with a non-zero `capacity`, [`try_push`]
//!   load-sheds (returns the request to the caller) once the queue is
//!   full; [`push_wait`] blocks for space instead (closed-loop callers
//!   like the compatibility `serve`).
//! * **SLO expiry** — requests carry an optional deadline
//!   ([`crate::serving::Request::deadline`]); [`next_batch`] drops
//!   expired requests *before* they consume a dispatch slot (never after
//!   a wasted forward pass), handing each to the drop hook so the
//!   runtime can resolve its handle.
//!
//! Observability: queue depth and its high-water mark ride the global
//! registry (`serving.queue.depth` gauge, `serving.queue.high_water`
//! gauge, `serving.batcher.expired` counter) — the signals the
//! autoscaler samples.
//!
//! [`try_push`]: DynamicBatcher::try_push
//! [`push_wait`]: DynamicBatcher::push_wait
//! [`next_batch`]: DynamicBatcher::next_batch

use super::request::Request;
use crate::metrics::{Counter, Gauge};
use crate::util::time::since_epoch;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Called with each request dropped in the queue (SLO expiry, purge on
/// close) so its handle can be resolved.
pub type DropHook = Box<dyn Fn(Request) + Send + Sync>;

struct Queue {
    items: VecDeque<Request>,
    closed: bool,
}

/// See module docs. Thread-safe: producers `push`/`try_push`/`push_wait`,
/// one consumer loops on `next_batch`.
pub struct DynamicBatcher {
    q: Mutex<Queue>,
    cv: Condvar,
    pub max_batch: usize,
    pub timeout: Duration,
    /// Admission bound (0 = unbounded).
    pub capacity: usize,
    high_water: AtomicUsize,
    drop_hook: Mutex<Option<DropHook>>,
    /// Pre-resolved global metrics (the push/drain paths are hot).
    depth_gauge: Arc<Gauge>,
    hw_gauge: Arc<Gauge>,
    expired_counter: Arc<Counter>,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, timeout: Duration) -> Arc<Self> {
        Self::with_capacity(max_batch, timeout, 0)
    }

    /// Batcher with a bounded admission queue (`capacity` requests;
    /// 0 = unbounded).
    pub fn with_capacity(max_batch: usize, timeout: Duration, capacity: usize) -> Arc<Self> {
        assert!(max_batch >= 1);
        let g = crate::metrics::global();
        Arc::new(DynamicBatcher {
            q: Mutex::new(Queue { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            max_batch,
            timeout,
            capacity,
            high_water: AtomicUsize::new(0),
            drop_hook: Mutex::new(None),
            depth_gauge: g.gauge("serving.queue.depth"),
            hw_gauge: g.gauge("serving.queue.high_water"),
            expired_counter: g.counter("serving.batcher.expired"),
        })
    }

    /// Install the hook invoked (outside the queue lock) for every
    /// request the batcher drops instead of dispatching.
    pub fn set_drop_hook(&self, hook: DropHook) {
        *self.drop_hook.lock().unwrap() = Some(hook);
    }

    fn note_depth(&self, depth: usize) {
        self.depth_gauge.set(depth as i64);
        let mut hw = self.high_water.load(Ordering::Relaxed);
        while depth > hw {
            match self.high_water.compare_exchange_weak(
                hw,
                depth,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.hw_gauge.set(depth as i64);
                    break;
                }
                Err(cur) => hw = cur,
            }
        }
    }

    fn run_drop_hook(&self, dropped: Vec<Request>) {
        if dropped.is_empty() {
            return;
        }
        self.expired_counter.add(dropped.len() as u64);
        let hook = self.drop_hook.lock().unwrap();
        if let Some(h) = hook.as_ref() {
            for r in dropped {
                h(r);
            }
        }
    }

    /// Enqueue a request unconditionally — bypasses the capacity bound
    /// and the closed flag (legacy/test path; a request pushed after
    /// `close` may never be drained). Production ingress goes through
    /// [`try_push`](Self::try_push) / [`push_wait`](Self::push_wait).
    /// Returns current queue depth (the controller's scaling signal).
    pub fn push(&self, r: Request) -> usize {
        let mut q = self.q.lock().unwrap();
        q.items.push_back(r);
        let depth = q.items.len();
        drop(q);
        self.note_depth(depth);
        self.cv.notify_all();
        depth
    }

    /// Admission-controlled enqueue: load-sheds (returns `Err` with the
    /// request) when the bounded queue is full or the batcher is closed.
    /// `Ok` carries the queue depth after the push.
    pub fn try_push(&self, r: Request) -> Result<usize, Request> {
        let mut q = self.q.lock().unwrap();
        if q.closed || (self.capacity > 0 && q.items.len() >= self.capacity) {
            return Err(r);
        }
        q.items.push_back(r);
        let depth = q.items.len();
        drop(q);
        self.note_depth(depth);
        self.cv.notify_all();
        Ok(depth)
    }

    /// Blocking enqueue: waits for queue space instead of shedding
    /// (closed-loop callers). `Err` returns the request if the batcher
    /// closed while waiting.
    pub fn push_wait(&self, r: Request) -> Result<usize, Request> {
        let mut q = self.q.lock().unwrap();
        while !q.closed && self.capacity > 0 && q.items.len() >= self.capacity {
            q = self.cv.wait(q).unwrap();
        }
        if q.closed {
            return Err(r);
        }
        q.items.push_back(r);
        let depth = q.items.len();
        drop(q);
        self.note_depth(depth);
        self.cv.notify_all();
        Ok(depth)
    }

    /// Queue depth right now.
    pub fn depth(&self) -> usize {
        self.q.lock().unwrap().items.len()
    }

    /// Highest queue depth ever observed (surfaced as the
    /// `serving.queue.high_water` gauge).
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::Relaxed)
    }

    /// No more requests will arrive; wake the consumer to drain and any
    /// blocked producers to bail.
    pub fn close(&self) {
        self.q.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Remove queued (not yet dispatched) requests whose ids are in
    /// `ids`, returning them (compatibility `serve` abandoning a timed
    /// out run; the drop hook is *not* invoked — the caller already
    /// resolved these).
    pub fn purge(&self, ids: &[u64]) -> Vec<Request> {
        let mut q = self.q.lock().unwrap();
        let mut purged = Vec::new();
        q.items.retain(|r| {
            if ids.contains(&r.id) {
                purged.push(r.clone());
                false
            } else {
                true
            }
        });
        let depth = q.items.len();
        drop(q);
        if !purged.is_empty() {
            self.note_depth(depth);
            self.cv.notify_all();
        }
        purged
    }

    /// Non-blocking slot-fill for the continuous decode loop: take up to
    /// `max` ready requests *right now* — no batch-fill window, no wait.
    /// Expired requests at the head are shed (drop hook) exactly as in
    /// [`next_batch`](Self::next_batch) and never consume a slot. The
    /// decode scheduler calls this once per iteration with however many
    /// slots its running batch has free; an empty return means the loop
    /// simply decodes whoever is already resident.
    pub fn take_ready(&self, max: usize) -> Vec<Request> {
        if max == 0 {
            return Vec::new();
        }
        let now = since_epoch();
        let mut q = self.q.lock().unwrap();
        let mut out = Vec::new();
        let mut expired = Vec::new();
        while out.len() < max {
            let Some(r) = q.items.pop_front() else { break };
            if r.expired_at(now) {
                expired.push(r);
            } else {
                out.push(r);
            }
        }
        let depth = q.items.len();
        drop(q);
        if !out.is_empty() || !expired.is_empty() {
            self.note_depth(depth);
            if self.capacity > 0 {
                self.cv.notify_all(); // space freed for push_wait
            }
        }
        self.run_drop_hook(expired);
        out
    }

    /// Blocking: wait for the first request, then fill up to `max_batch`
    /// until `timeout` elapses. Expired requests are dropped (drop hook)
    /// before dispatch and never consume a batch slot. `None` once
    /// closed and drained.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        loop {
            let mut q = self.q.lock().unwrap();
            // Phase 1: wait for anything. The condvar is notified by
            // push/close, so no poll cap is needed.
            loop {
                if !q.items.is_empty() {
                    break;
                }
                if q.closed {
                    return None;
                }
                q = self.cv.wait(q).unwrap();
            }
            // Phase 2: batch-fill window.
            let deadline = Instant::now() + self.timeout;
            loop {
                if q.items.len() >= self.max_batch || q.closed {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                q = self.cv.wait_timeout(q, deadline - now).unwrap().0;
            }
            // Drain: fill the batch from the front, shedding expired
            // requests so they never occupy a dispatch slot.
            let now = since_epoch();
            let mut batch = Vec::new();
            let mut expired = Vec::new();
            while batch.len() < self.max_batch {
                let Some(r) = q.items.pop_front() else { break };
                if r.expired_at(now) {
                    expired.push(r);
                } else {
                    batch.push(r);
                }
            }
            let depth = q.items.len();
            drop(q);
            self.note_depth(depth);
            if self.capacity > 0 {
                self.cv.notify_all(); // space freed for push_wait
            }
            self.run_drop_hook(expired);
            if !batch.is_empty() {
                return Some(batch);
            }
            // Everything drained was expired — wait for fresh work.
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![0; 4])
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let b = DynamicBatcher::new(4, Duration::from_secs(10));
        for i in 0..4 {
            b.push(req(i));
        }
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert!(t0.elapsed() < Duration::from_millis(100), "no timeout wait");
    }

    #[test]
    fn partial_batch_waits_for_timeout() {
        let b = DynamicBatcher::new(8, Duration::from_millis(60));
        b.push(req(0));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(50));
    }

    #[test]
    fn late_arrivals_join_the_window() {
        let b = DynamicBatcher::new(8, Duration::from_millis(150));
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            b2.push(req(1));
            b2.push(req(2));
        });
        b.push(req(0));
        let batch = b.next_batch().unwrap();
        t.join().unwrap();
        assert_eq!(batch.len(), 3);
    }

    #[test]
    fn fifo_order_preserved() {
        let b = DynamicBatcher::new(3, Duration::from_millis(10));
        for i in 0..7 {
            b.push(req(i));
        }
        let b1 = b.next_batch().unwrap();
        let b2 = b.next_batch().unwrap();
        let b3 = b.next_batch().unwrap();
        let ids: Vec<u64> = b1.iter().chain(&b2).chain(&b3).map(|r| r.id).collect();
        assert_eq!(ids, (0..7).collect::<Vec<_>>());
        assert_eq!((b1.len(), b2.len(), b3.len()), (3, 3, 1));
    }

    #[test]
    fn close_drains_then_none() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        b.push(req(0));
        b.close();
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let b = DynamicBatcher::new(4, Duration::from_secs(10));
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(30));
        b.close();
        assert!(t.join().unwrap().is_none());
    }

    #[test]
    fn push_wakes_blocked_consumer_without_poll_cap() {
        // Regression for the old 50 ms phase-1 poll: a push must wake
        // the consumer promptly via the condvar alone.
        let b = DynamicBatcher::new(1, Duration::from_millis(1));
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        let t0 = Instant::now();
        b.push(req(0));
        let batch = t.join().unwrap().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_millis(40),
            "woken by notify, not a 50 ms poll"
        );
    }

    #[test]
    fn depth_reporting_and_high_water() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        assert_eq!(b.push(req(0)), 1);
        assert_eq!(b.push(req(1)), 2);
        assert_eq!(b.depth(), 2);
        let _ = b.next_batch();
        assert_eq!(b.depth(), 0);
        assert_eq!(b.high_water(), 2, "high water survives the drain");
    }

    #[test]
    fn bounded_queue_sheds_then_admits_after_drain() {
        let b = DynamicBatcher::with_capacity(2, Duration::from_millis(5), 3);
        for i in 0..3 {
            assert!(b.try_push(req(i)).is_ok());
        }
        let back = b.try_push(req(3)).unwrap_err();
        assert_eq!(back.id, 3, "shed request returned to the caller");
        let _ = b.next_batch().unwrap(); // drains 2
        assert!(b.try_push(req(4)).is_ok(), "space after drain");
    }

    #[test]
    fn push_wait_blocks_for_space() {
        let b = DynamicBatcher::with_capacity(1, Duration::from_millis(1), 1);
        assert!(b.try_push(req(0)).is_ok());
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.push_wait(req(1)));
        std::thread::sleep(Duration::from_millis(20));
        let _ = b.next_batch().unwrap(); // frees the slot
        assert!(t.join().unwrap().is_ok());
        assert_eq!(b.depth(), 1);
    }

    #[test]
    fn push_wait_bails_on_close() {
        let b = DynamicBatcher::with_capacity(1, Duration::from_millis(1), 1);
        assert!(b.try_push(req(0)).is_ok());
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.push_wait(req(1)));
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        let back = t.join().unwrap().unwrap_err();
        assert_eq!(back.id, 1);
    }

    #[test]
    fn expired_requests_dropped_before_dispatch() {
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        let dropped: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let d2 = dropped.clone();
        b.set_drop_hook(Box::new(move |r| d2.lock().unwrap().push(r.id)));
        let mut dead = req(0);
        dead.deadline = Some(since_epoch() - 1.0); // already expired
        let live = req(1);
        b.push(dead);
        b.push(live);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1, "expired request never reaches dispatch");
        assert_eq!(dropped.lock().unwrap().as_slice(), &[0]);
    }

    #[test]
    fn all_expired_waits_for_fresh_work() {
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        let mut dead = req(0);
        dead.deadline = Some(since_epoch() - 1.0);
        b.push(dead);
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(30));
        b.push(req(1)); // fresh work arrives after the expired drain
        let batch = t.join().unwrap().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1);
    }

    #[test]
    fn concurrent_pushers_consumer_purger_account_for_every_request() {
        // Brute-force concurrency coverage for the condvar paths PR 4
        // added: three producers race seeded mixes of `try_push` (shed)
        // and `push_wait` (backpressure) — some requests pre-expired so
        // the drop hook fires concurrently with the drain — while one
        // consumer loops `next_batch` and a purger rips queued ids out
        // from under everyone. Three seeds give three interleaving
        // families. The invariant: every request resolves to exactly ONE
        // fate (dispatched, hook-dropped, shed, or purged) — no loss, no
        // duplication, queue empty at the end.
        use crate::util::prng::Rng;
        const PRODUCERS: u64 = 3;
        const PER_PRODUCER: u64 = 120;
        for seed in [1u64, 7, 42] {
            let b = DynamicBatcher::with_capacity(4, Duration::from_millis(1), 8);
            let dropped: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
            let d2 = dropped.clone();
            b.set_drop_hook(Box::new(move |r| d2.lock().unwrap().push(r.id)));
            let shed: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
            let producers: Vec<_> = (0..PRODUCERS)
                .map(|p| {
                    let b = b.clone();
                    let shed = shed.clone();
                    std::thread::spawn(move || {
                        let mut rng = Rng::new(seed * 1000 + p);
                        for k in 0..PER_PRODUCER {
                            let mut r = req(p * PER_PRODUCER + k);
                            if rng.chance(0.2) {
                                r.deadline = Some(since_epoch() - 1.0); // pre-expired
                            }
                            let res = if rng.chance(0.5) {
                                b.try_push(r)
                            } else {
                                b.push_wait(r)
                            };
                            if let Err(back) = res {
                                shed.lock().unwrap().push(back.id);
                            }
                            if rng.chance(0.1) {
                                std::thread::yield_now();
                            }
                        }
                    })
                })
                .collect();
            let stop_purge = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let purger = {
                let b = b.clone();
                let stop = stop_purge.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::new(seed ^ 0xBADC0FFE);
                    let mut purged = Vec::new();
                    while !stop.load(Ordering::Relaxed) {
                        let id = rng.below(PRODUCERS * PER_PRODUCER);
                        purged.extend(b.purge(&[id]).into_iter().map(|r| r.id));
                        std::thread::yield_now();
                    }
                    purged
                })
            };
            let consumer = {
                let b = b.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(batch) = b.next_batch() {
                        got.extend(batch.into_iter().map(|r| r.id));
                    }
                    got
                })
            };
            for p in producers {
                p.join().unwrap();
            }
            stop_purge.store(true, Ordering::Relaxed);
            let purged = purger.join().unwrap();
            b.close();
            let dispatched = consumer.join().unwrap();

            let total = (PRODUCERS * PER_PRODUCER) as usize;
            let mut all: Vec<u64> = dispatched;
            all.extend(dropped.lock().unwrap().iter().copied());
            all.extend(shed.lock().unwrap().iter().copied());
            all.extend(purged.iter().copied());
            all.sort_unstable();
            assert_eq!(
                all.len(),
                total,
                "seed {seed}: every request must resolve to exactly one fate"
            );
            all.dedup();
            assert_eq!(all.len(), total, "seed {seed}: no id resolved twice");
            assert_eq!(b.depth(), 0, "seed {seed}: queue drained");
        }
    }

    #[test]
    fn take_ready_fills_free_slots_without_blocking() {
        let b = DynamicBatcher::new(8, Duration::from_secs(10));
        // Empty queue: returns immediately with nothing (the decode loop
        // just runs the residents) — no batch-fill wait.
        let t0 = Instant::now();
        assert!(b.take_ready(4).is_empty());
        assert!(t0.elapsed() < Duration::from_millis(50), "never blocks");
        for i in 0..3 {
            b.push(req(i));
        }
        // Two free slots → exactly two admitted, FIFO; the third stays.
        let got: Vec<u64> = b.take_ready(2).iter().map(|r| r.id).collect();
        assert_eq!(got, vec![0, 1]);
        assert_eq!(b.depth(), 1);
        // Zero free slots is a no-op.
        assert!(b.take_ready(0).is_empty());
        assert_eq!(b.depth(), 1);
    }

    #[test]
    fn continuous_admission_joins_and_leaves_across_steps() {
        // Model the decode loop: a 4-slot running batch where requests
        // retire at different steps and `take_ready` back-fills exactly
        // the freed slots each iteration — requests join and leave the
        // batch mid-flight instead of gang-scheduling.
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        for i in 0..4 {
            b.push(req(i));
        }
        let mut resident: Vec<u64> =
            b.take_ready(4).iter().map(|r| r.id).collect();
        assert_eq!(resident, vec![0, 1, 2, 3]);
        // Step 1: requests 1 and 3 finish; two arrivals land mid-step.
        b.push(req(4));
        b.push(req(5));
        resident.retain(|&id| id != 1 && id != 3);
        let joined: Vec<u64> =
            b.take_ready(4 - resident.len()).iter().map(|r| r.id).collect();
        assert_eq!(joined, vec![4, 5], "arrivals fill freed slots same step");
        resident.extend(joined);
        assert_eq!(resident.len(), 4, "batch stays full across churn");
        // Step 2: nothing queued, one retirement — the loop keeps
        // decoding a partial batch rather than stalling for a fill.
        resident.retain(|&id| id != 0);
        assert!(b.take_ready(4 - resident.len()).is_empty());
        assert_eq!(resident, vec![2, 4, 5]);
    }

    #[test]
    fn head_of_queue_slo_drops_race_slot_admission() {
        // An expired request at the head must be shed by `take_ready`
        // (drop hook, no slot consumed) even while pushers are racing
        // admission — the streaming analogue of
        // `expired_requests_dropped_before_dispatch`.
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        let dropped: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let d2 = dropped.clone();
        b.set_drop_hook(Box::new(move |r| d2.lock().unwrap().push(r.id)));
        let mut dead = req(0);
        dead.deadline = Some(since_epoch() - 1.0);
        b.push(dead);
        let b2 = b.clone();
        let racer = std::thread::spawn(move || {
            for i in 1..=8 {
                b2.push(req(i));
                std::thread::yield_now();
            }
        });
        // Keep taking one slot at a time while the racer pushes: the
        // dead head must surface in the drop hook, never in a slot.
        let mut admitted = Vec::new();
        while admitted.len() < 8 {
            admitted.extend(b.take_ready(1).iter().map(|r| r.id));
        }
        racer.join().unwrap();
        assert_eq!(dropped.lock().unwrap().as_slice(), &[0]);
        let mut sorted = admitted.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn shedding_while_running_batch_full() {
        // The running batch is full (take_ready(0) every step), so the
        // bounded queue backs up and admission load-sheds — exactly the
        // saturation regime continuous batching runs in.
        let b = DynamicBatcher::with_capacity(4, Duration::from_millis(1), 2);
        assert!(b.try_push(req(0)).is_ok());
        assert!(b.try_push(req(1)).is_ok());
        let back = b.try_push(req(2)).unwrap_err();
        assert_eq!(back.id, 2, "full queue sheds while the batch is full");
        // Several decode steps pass with no free slots: nothing drains,
        // shedding continues deterministically.
        for _ in 0..3 {
            assert!(b.take_ready(0).is_empty());
            assert!(b.try_push(req(9)).is_err());
        }
        // One retirement frees one slot; one queued request admits and
        // exactly one shed producer gets space back.
        assert_eq!(b.take_ready(1)[0].id, 0);
        assert!(b.try_push(req(3)).is_ok());
        assert!(b.try_push(req(4)).is_err(), "queue full again");
    }

    #[test]
    fn purge_removes_queued_ids() {
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        for i in 0..4 {
            b.push(req(i));
        }
        let purged = b.purge(&[1, 3]);
        assert_eq!(purged.len(), 2);
        assert_eq!(b.depth(), 2);
        let ids: Vec<u64> = b.next_batch().unwrap().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2]);
    }
}
