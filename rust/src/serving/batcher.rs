//! Dynamic batching: fuse queued requests into model-batch-sized groups,
//! dispatching when the batch fills or a deadline expires (vLLM-style
//! continuous batching simplified to the fixed-batch AOT executable).

use super::request::Request;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct Queue {
    items: VecDeque<Request>,
    closed: bool,
}

/// See module docs. Thread-safe: producers `push`, one consumer loops on
/// `next_batch`.
pub struct DynamicBatcher {
    q: Mutex<Queue>,
    cv: Condvar,
    pub max_batch: usize,
    pub timeout: Duration,
    depth_high_water: AtomicBool,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, timeout: Duration) -> Arc<Self> {
        assert!(max_batch >= 1);
        Arc::new(DynamicBatcher {
            q: Mutex::new(Queue { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            max_batch,
            timeout,
            depth_high_water: AtomicBool::new(false),
        })
    }

    /// Enqueue a request. Returns current queue depth (for the
    /// controller's scaling signal).
    pub fn push(&self, r: Request) -> usize {
        let mut q = self.q.lock().unwrap();
        q.items.push_back(r);
        let depth = q.items.len();
        self.cv.notify_one();
        depth
    }

    /// Queue depth right now.
    pub fn depth(&self) -> usize {
        self.q.lock().unwrap().items.len()
    }

    /// No more requests will arrive; wake the consumer to drain.
    pub fn close(&self) {
        self.q.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Blocking: wait for the first request, then fill up to `max_batch`
    /// until `timeout` elapses. `None` once closed and drained.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let mut q = self.q.lock().unwrap();
        // Phase 1: wait for anything.
        loop {
            if !q.items.is_empty() {
                break;
            }
            if q.closed {
                return None;
            }
            q = self.cv.wait_timeout(q, Duration::from_millis(50)).unwrap().0;
        }
        // Phase 2: batch-fill window.
        let deadline = Instant::now() + self.timeout;
        loop {
            if q.items.len() >= self.max_batch || q.closed {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            q = self.cv.wait_timeout(q, deadline - now).unwrap().0;
        }
        let n = q.items.len().min(self.max_batch);
        let batch: Vec<Request> = q.items.drain(..n).collect();
        self.depth_high_water
            .fetch_or(q.items.len() > self.max_batch, Ordering::Relaxed);
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![0; 4])
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let b = DynamicBatcher::new(4, Duration::from_secs(10));
        for i in 0..4 {
            b.push(req(i));
        }
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert!(t0.elapsed() < Duration::from_millis(100), "no timeout wait");
    }

    #[test]
    fn partial_batch_waits_for_timeout() {
        let b = DynamicBatcher::new(8, Duration::from_millis(60));
        b.push(req(0));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(50));
    }

    #[test]
    fn late_arrivals_join_the_window() {
        let b = DynamicBatcher::new(8, Duration::from_millis(150));
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            b2.push(req(1));
            b2.push(req(2));
        });
        b.push(req(0));
        let batch = b.next_batch().unwrap();
        t.join().unwrap();
        assert_eq!(batch.len(), 3);
    }

    #[test]
    fn fifo_order_preserved() {
        let b = DynamicBatcher::new(3, Duration::from_millis(10));
        for i in 0..7 {
            b.push(req(i));
        }
        let b1 = b.next_batch().unwrap();
        let b2 = b.next_batch().unwrap();
        let b3 = b.next_batch().unwrap();
        let ids: Vec<u64> = b1.iter().chain(&b2).chain(&b3).map(|r| r.id).collect();
        assert_eq!(ids, (0..7).collect::<Vec<_>>());
        assert_eq!((b1.len(), b2.len(), b3.len()), (3, 3, 1));
    }

    #[test]
    fn close_drains_then_none() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        b.push(req(0));
        b.close();
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let b = DynamicBatcher::new(4, Duration::from_secs(10));
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(30));
        b.close();
        assert!(t.join().unwrap().is_none());
    }

    #[test]
    fn depth_reporting() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        assert_eq!(b.push(req(0)), 1);
        assert_eq!(b.push(req(1)), 2);
        assert_eq!(b.depth(), 2);
        let _ = b.next_batch();
        assert_eq!(b.depth(), 0);
    }
}
