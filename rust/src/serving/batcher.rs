//! Deadline-aware admission queue + dynamic batcher: fuse queued
//! requests into model-batch-sized groups, dispatching when the batch
//! fills or a deadline expires (vLLM-style continuous batching
//! simplified to the fixed-batch AOT executable).
//!
//! Admission control:
//!
//! * **Bounded depth** — with a non-zero `capacity`, [`try_push`]
//!   load-sheds (returns the request to the caller) once the queue is
//!   full; [`push_wait`] blocks for space instead (closed-loop callers
//!   like the compatibility `serve`).
//! * **SLO expiry** — requests carry an optional deadline
//!   ([`crate::serving::Request::deadline`]); [`next_batch`] drops
//!   expired requests *before* they consume a dispatch slot (never after
//!   a wasted forward pass), handing each to the drop hook so the
//!   runtime can resolve its handle.
//!
//! Multi-tenant admission ([`with_tenants`]): each configured
//! [`TenantClass`] gets its own sub-queue, drained by deficit
//! round-robin — every visit grants a tenant `weight` dispatch credits,
//! so under backlog tenants drain in weight ratio (4:1 weights → 4:1
//! slots) while each tenant's own traffic stays FIFO. Depth bounds are
//! *per tenant* (a class's `depth`, inheriting the global `capacity`
//! when 0), so a bursting tenant sheds its own traffic first and never
//! consumes another tenant's admission budget. Requests tagged with an
//! unknown tenant fold into the implicit `default` class. With no
//! tenant table the batcher degenerates to the exact single-FIFO
//! behavior above — same order, same bounds, same metric names.
//!
//! Observability: queue depth and its high-water mark ride the global
//! registry (`serving.queue.depth` gauge, `serving.queue.high_water`
//! gauge, `serving.batcher.expired` counter) — the signals the
//! autoscaler samples. Tenant-aware batchers additionally publish
//! `serving.queue.depth.tenant.<name>` per class. Gauges are published
//! *while the queue lock is held* so concurrent pushes can never
//! publish depths out of order and pin the gauge stale-low.
//!
//! [`try_push`]: DynamicBatcher::try_push
//! [`push_wait`]: DynamicBatcher::push_wait
//! [`next_batch`]: DynamicBatcher::next_batch
//! [`with_tenants`]: DynamicBatcher::with_tenants

use super::request::{DropReason, Request, TenantId, DEFAULT_TENANT};
use crate::metrics::{Counter, Gauge};
use crate::util::time::since_epoch;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Called with each request dropped in the queue (SLO expiry, purge on
/// close, push into a closed queue) so its handle can be resolved with
/// the given reason.
pub type DropHook = Box<dyn Fn(Request, DropReason) + Send + Sync>;

/// One tenant's admission class: DRR weight and queue-depth bound.
#[derive(Clone, Debug)]
pub struct TenantClass {
    pub name: String,
    /// DRR quantum: dispatch slots granted per rotation visit (≥ 1).
    pub weight: u32,
    /// Per-tenant admission bound; 0 inherits the batcher's global
    /// `capacity`.
    pub depth: usize,
}

impl TenantClass {
    pub fn new(name: &str, weight: u32, depth: usize) -> Self {
        TenantClass { name: name.to_string(), weight, depth }
    }
}

/// Resolved per-class state (weights, bounds, pre-resolved gauge).
struct ClassCfg {
    weight: u64,
    depth: usize,
    gauge: Arc<Gauge>,
}

struct SubQueue {
    items: VecDeque<Request>,
    /// Unspent DRR credits. Non-zero only when a drain stopped mid-visit
    /// because the output filled; the next drain resumes here.
    deficit: u64,
}

struct Queue {
    /// Per-tenant sub-queues. Invariant: a tenant is present iff its
    /// sub-queue is non-empty, and `rotation` lists exactly those
    /// tenants in DRR visit order.
    subs: BTreeMap<TenantId, SubQueue>,
    rotation: VecDeque<TenantId>,
    /// Total queued requests across all sub-queues.
    total: usize,
    closed: bool,
}

/// See module docs. Thread-safe: producers `push`/`try_push`/`push_wait`,
/// one consumer loops on `next_batch`.
pub struct DynamicBatcher {
    q: Mutex<Queue>,
    cv: Condvar,
    pub max_batch: usize,
    pub timeout: Duration,
    /// Global admission bound (0 = unbounded). Tenant-aware batchers
    /// bound admission per class instead; a class with `depth == 0`
    /// inherits this value.
    pub capacity: usize,
    /// Tenant classes (empty = single-tenant FIFO).
    classes: BTreeMap<TenantId, ClassCfg>,
    /// Any admission bound at all (drains must wake blocked producers).
    bounded: bool,
    default_tenant: TenantId,
    high_water: AtomicUsize,
    drop_hook: Mutex<Option<DropHook>>,
    /// Pre-resolved global metrics (the push/drain paths are hot).
    depth_gauge: Arc<Gauge>,
    hw_gauge: Arc<Gauge>,
    expired_counter: Arc<Counter>,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, timeout: Duration) -> Arc<Self> {
        Self::with_capacity(max_batch, timeout, 0)
    }

    /// Batcher with a bounded admission queue (`capacity` requests;
    /// 0 = unbounded).
    pub fn with_capacity(max_batch: usize, timeout: Duration, capacity: usize) -> Arc<Self> {
        Self::with_tenants(max_batch, timeout, capacity, &[])
    }

    /// Tenant-aware batcher: weighted-fair admission across `classes`
    /// (empty = the single-tenant batcher of
    /// [`with_capacity`](Self::with_capacity)). An implicit `default`
    /// class (weight 1, depth inherited) is added when absent so
    /// untagged and unknown tenants stay schedulable.
    pub fn with_tenants(
        max_batch: usize,
        timeout: Duration,
        capacity: usize,
        classes: &[TenantClass],
    ) -> Arc<Self> {
        assert!(max_batch >= 1);
        let g = crate::metrics::global();
        let default_tenant = TenantId::default();
        let mut map = BTreeMap::new();
        if !classes.is_empty() {
            for c in classes {
                map.insert(
                    TenantId::new(&c.name),
                    ClassCfg {
                        weight: u64::from(c.weight.max(1)),
                        depth: c.depth,
                        gauge: g.gauge(&format!("serving.queue.depth.tenant.{}", c.name)),
                    },
                );
            }
            map.entry(default_tenant.clone()).or_insert_with(|| ClassCfg {
                weight: 1,
                depth: 0,
                gauge: g.gauge(&format!("serving.queue.depth.tenant.{DEFAULT_TENANT}")),
            });
        }
        let bounded = capacity > 0 || map.values().any(|c| c.depth > 0);
        Arc::new(DynamicBatcher {
            q: Mutex::new(Queue {
                subs: BTreeMap::new(),
                rotation: VecDeque::new(),
                total: 0,
                closed: false,
            }),
            cv: Condvar::new(),
            max_batch,
            timeout,
            capacity,
            classes: map,
            bounded,
            default_tenant,
            high_water: AtomicUsize::new(0),
            drop_hook: Mutex::new(None),
            depth_gauge: g.gauge("serving.queue.depth"),
            hw_gauge: g.gauge("serving.queue.high_water"),
            expired_counter: g.counter("serving.batcher.expired"),
        })
    }

    /// Install the hook invoked (outside the queue lock) for every
    /// request the batcher drops instead of dispatching.
    pub fn set_drop_hook(&self, hook: DropHook) {
        *self.drop_hook.lock().unwrap() = Some(hook);
    }

    /// Whether this batcher runs weighted-fair multi-tenant admission.
    pub fn tenant_aware(&self) -> bool {
        !self.classes.is_empty()
    }

    /// The admission class a request's tenant lands in: itself when
    /// configured, otherwise the implicit default (which is also the
    /// single class of a non-tenant-aware batcher).
    fn class_of(&self, t: &TenantId) -> TenantId {
        if self.classes.contains_key(t) {
            t.clone()
        } else {
            self.default_tenant.clone()
        }
    }

    /// Admission check for one class. Tenant-aware batchers bound each
    /// sub-queue independently so a burster sheds its own traffic
    /// first; the legacy batcher bounds the whole queue.
    fn full_locked(&self, q: &Queue, class: &TenantId) -> bool {
        match self.classes.get(class) {
            Some(c) => {
                let limit = if c.depth > 0 { c.depth } else { self.capacity };
                limit > 0 && q.subs.get(class).map_or(0, |s| s.items.len()) >= limit
            }
            None => self.capacity > 0 && q.total >= self.capacity,
        }
    }

    fn enqueue_locked(&self, q: &mut Queue, r: Request) {
        let t = self.class_of(&r.tenant);
        let sub = q
            .subs
            .entry(t.clone())
            .or_insert_with(|| SubQueue { items: VecDeque::new(), deficit: 0 });
        if sub.items.is_empty() {
            q.rotation.push_back(t);
        }
        sub.items.push_back(r);
        q.total += 1;
    }

    /// Publish depth gauges from a coherent snapshot. MUST be called
    /// with the queue lock held: publishing after the lock drops lets
    /// two racing pushes publish out of order and pin the gauge
    /// stale-low — the autoscaler's primary signal.
    fn note_depth_locked(&self, q: &Queue) {
        let depth = q.total;
        self.depth_gauge.set(depth as i64);
        for (t, c) in &self.classes {
            c.gauge.set(q.subs.get(t).map_or(0, |s| s.items.len()) as i64);
        }
        let mut hw = self.high_water.load(Ordering::Relaxed);
        while depth > hw {
            match self.high_water.compare_exchange_weak(
                hw,
                depth,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.hw_gauge.set(depth as i64);
                    break;
                }
                Err(cur) => hw = cur,
            }
        }
    }

    fn run_drop_hook(&self, dropped: Vec<Request>, reason: DropReason) {
        if dropped.is_empty() {
            return;
        }
        if reason == DropReason::Deadline {
            self.expired_counter.add(dropped.len() as u64);
        }
        let hook = self.drop_hook.lock().unwrap();
        if let Some(h) = hook.as_ref() {
            for r in dropped {
                h(r, reason);
            }
        }
    }

    /// Enqueue a request unconditionally — bypasses the capacity bound
    /// (legacy/test path). A request pushed after `close` is handed to
    /// the drop hook as [`DropReason::Shutdown`] instead of being
    /// enqueued into a queue nobody will ever drain, so every submitted
    /// id still resolves to exactly one outcome. Production ingress
    /// goes through [`try_push`](Self::try_push) /
    /// [`push_wait`](Self::push_wait). Returns current queue depth (the
    /// controller's scaling signal).
    pub fn push(&self, r: Request) -> usize {
        let mut q = self.q.lock().unwrap();
        if q.closed {
            let depth = q.total;
            drop(q);
            self.run_drop_hook(vec![r], DropReason::Shutdown);
            return depth;
        }
        self.enqueue_locked(&mut q, r);
        self.note_depth_locked(&q);
        let depth = q.total;
        drop(q);
        self.cv.notify_all();
        depth
    }

    /// Admission-controlled enqueue: load-sheds (returns `Err` with the
    /// request) when the bounded queue is full or the batcher is closed.
    /// `Ok` carries the queue depth after the push.
    pub fn try_push(&self, r: Request) -> Result<usize, Request> {
        let mut q = self.q.lock().unwrap();
        let class = self.class_of(&r.tenant);
        if q.closed || self.full_locked(&q, &class) {
            return Err(r);
        }
        self.enqueue_locked(&mut q, r);
        self.note_depth_locked(&q);
        let depth = q.total;
        drop(q);
        self.cv.notify_all();
        Ok(depth)
    }

    /// Blocking enqueue: waits for queue space instead of shedding
    /// (closed-loop callers). `Err` returns the request if the batcher
    /// closed while waiting.
    pub fn push_wait(&self, r: Request) -> Result<usize, Request> {
        let mut q = self.q.lock().unwrap();
        let class = self.class_of(&r.tenant);
        while !q.closed && self.full_locked(&q, &class) {
            q = self.cv.wait(q).unwrap();
        }
        if q.closed {
            return Err(r);
        }
        self.enqueue_locked(&mut q, r);
        self.note_depth_locked(&q);
        let depth = q.total;
        drop(q);
        self.cv.notify_all();
        Ok(depth)
    }

    /// Queue depth right now (all tenants).
    pub fn depth(&self) -> usize {
        self.q.lock().unwrap().total
    }

    /// Per-tenant queue depths for every configured class (empty on a
    /// single-tenant batcher) — the autoscaler's per-tenant signal.
    pub fn tenant_depths(&self) -> Vec<(TenantId, usize)> {
        let q = self.q.lock().unwrap();
        self.classes
            .keys()
            .map(|t| (t.clone(), q.subs.get(t).map_or(0, |s| s.items.len())))
            .collect()
    }

    /// Highest queue depth ever observed (surfaced as the
    /// `serving.queue.high_water` gauge).
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::Relaxed)
    }

    /// No more requests will arrive; wake the consumer to drain and any
    /// blocked producers to bail.
    pub fn close(&self) {
        self.q.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Remove queued (not yet dispatched) requests whose ids are in
    /// `ids`, returning them (compatibility `serve` abandoning a timed
    /// out run; the drop hook is *not* invoked — the caller already
    /// resolved these).
    pub fn purge(&self, ids: &[u64]) -> Vec<Request> {
        let mut q = self.q.lock().unwrap();
        let mut purged = Vec::new();
        let qm = &mut *q;
        for sub in qm.subs.values_mut() {
            sub.items.retain(|r| {
                if ids.contains(&r.id) {
                    purged.push(r.clone());
                    false
                } else {
                    true
                }
            });
        }
        if !purged.is_empty() {
            qm.total -= purged.len();
            qm.subs.retain(|_, s| !s.items.is_empty());
            let subs = &qm.subs;
            qm.rotation.retain(|t| subs.contains_key(t));
            self.note_depth_locked(&q);
            drop(q);
            self.cv.notify_all();
        }
        purged
    }

    /// Deficit-round-robin drain of up to `max` live requests. Each
    /// rotation visit grants the head tenant `weight` credits; live
    /// requests cost one credit, expired requests are shed for free
    /// (collected into the returned expiry list, never consuming a
    /// dispatch slot). A tenant whose credits run out rotates to the
    /// back; a tenant emptied mid-visit leaves the rotation; when the
    /// output fills mid-visit the tenant keeps its unspent credits and
    /// stays at the head so the next drain resumes exactly where this
    /// one stopped. With a single class this is exact FIFO.
    fn drain_locked(&self, q: &mut Queue, max: usize, now: f64) -> (Vec<Request>, Vec<Request>) {
        let mut out = Vec::new();
        let mut expired = Vec::new();
        let Queue { subs, rotation, total, .. } = q;
        'rounds: while out.len() < max && *total > 0 {
            let t = rotation
                .front()
                .expect("rotation tracks non-empty sub-queues")
                .clone();
            let quantum = self.classes.get(&t).map_or(1, |c| c.weight);
            let sub = subs.get_mut(&t).unwrap();
            if sub.deficit == 0 {
                sub.deficit = quantum;
            }
            while sub.deficit > 0 {
                if out.len() >= max {
                    // Leaving mid-visit: keep the invariant that the
                    // rotation lists exactly the non-empty sub-queues.
                    if sub.items.is_empty() {
                        subs.remove(&t);
                        rotation.pop_front();
                    }
                    break 'rounds;
                }
                let Some(r) = sub.items.pop_front() else { break };
                *total -= 1;
                if r.expired_at(now) {
                    expired.push(r);
                } else {
                    sub.deficit -= 1;
                    out.push(r);
                }
            }
            if sub.items.is_empty() {
                subs.remove(&t);
                rotation.pop_front();
            } else {
                rotation.rotate_left(1);
            }
        }
        (out, expired)
    }

    /// Non-blocking slot-fill for the continuous decode loop: take up to
    /// `max` ready requests *right now* — no batch-fill window, no wait.
    /// Expired requests at the head are shed (drop hook) exactly as in
    /// [`next_batch`](Self::next_batch) and never consume a slot. The
    /// decode scheduler calls this once per iteration with however many
    /// slots its running batch has free; an empty return means the loop
    /// simply decodes whoever is already resident. On a tenant-aware
    /// batcher slots fill by DRR, so decode admission respects the same
    /// weighted shares as batch dispatch.
    pub fn take_ready(&self, max: usize) -> Vec<Request> {
        if max == 0 {
            return Vec::new();
        }
        let now = since_epoch();
        let mut q = self.q.lock().unwrap();
        let (out, expired) = self.drain_locked(&mut q, max, now);
        let touched = !out.is_empty() || !expired.is_empty();
        if touched {
            self.note_depth_locked(&q);
        }
        drop(q);
        if touched && self.bounded {
            self.cv.notify_all(); // space freed for push_wait
        }
        self.run_drop_hook(expired, DropReason::Deadline);
        out
    }

    /// Blocking: wait for the first request, then fill up to `max_batch`
    /// until `timeout` elapses. Expired requests are dropped (drop hook)
    /// before dispatch and never consume a batch slot. `None` once
    /// closed and drained.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        loop {
            let mut q = self.q.lock().unwrap();
            // Phase 1: wait for anything. The condvar is notified by
            // push/close, so no poll cap is needed.
            loop {
                if q.total > 0 {
                    break;
                }
                if q.closed {
                    return None;
                }
                q = self.cv.wait(q).unwrap();
            }
            // Phase 2: batch-fill window.
            let deadline = Instant::now() + self.timeout;
            loop {
                if q.total >= self.max_batch || q.closed {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                q = self.cv.wait_timeout(q, deadline - now).unwrap().0;
            }
            // Drain: fill the batch by DRR (single-tenant = front-first
            // FIFO), shedding expired requests so they never occupy a
            // dispatch slot.
            let now = since_epoch();
            let (batch, expired) = self.drain_locked(&mut q, self.max_batch, now);
            self.note_depth_locked(&q);
            drop(q);
            if self.bounded {
                self.cv.notify_all(); // space freed for push_wait
            }
            self.run_drop_hook(expired, DropReason::Deadline);
            if !batch.is_empty() {
                return Some(batch);
            }
            // Everything drained was expired — wait for fresh work.
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![0; 4])
    }

    fn treq(id: u64, tenant: &str) -> Request {
        req(id).with_tenant(tenant)
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let b = DynamicBatcher::new(4, Duration::from_secs(10));
        for i in 0..4 {
            b.push(req(i));
        }
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert!(t0.elapsed() < Duration::from_millis(100), "no timeout wait");
    }

    #[test]
    fn partial_batch_waits_for_timeout() {
        let b = DynamicBatcher::new(8, Duration::from_millis(60));
        b.push(req(0));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(50));
    }

    #[test]
    fn late_arrivals_join_the_window() {
        let b = DynamicBatcher::new(8, Duration::from_millis(150));
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            b2.push(req(1));
            b2.push(req(2));
        });
        b.push(req(0));
        let batch = b.next_batch().unwrap();
        t.join().unwrap();
        assert_eq!(batch.len(), 3);
    }

    #[test]
    fn fifo_order_preserved() {
        let b = DynamicBatcher::new(3, Duration::from_millis(10));
        for i in 0..7 {
            b.push(req(i));
        }
        let b1 = b.next_batch().unwrap();
        let b2 = b.next_batch().unwrap();
        let b3 = b.next_batch().unwrap();
        let ids: Vec<u64> = b1.iter().chain(&b2).chain(&b3).map(|r| r.id).collect();
        assert_eq!(ids, (0..7).collect::<Vec<_>>());
        assert_eq!((b1.len(), b2.len(), b3.len()), (3, 3, 1));
    }

    #[test]
    fn close_drains_then_none() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        b.push(req(0));
        b.close();
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let b = DynamicBatcher::new(4, Duration::from_secs(10));
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(30));
        b.close();
        assert!(t.join().unwrap().is_none());
    }

    #[test]
    fn push_wakes_blocked_consumer_without_poll_cap() {
        // Regression for the old 50 ms phase-1 poll: a push must wake
        // the consumer promptly via the condvar alone.
        let b = DynamicBatcher::new(1, Duration::from_millis(1));
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        let t0 = Instant::now();
        b.push(req(0));
        let batch = t.join().unwrap().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_millis(40),
            "woken by notify, not a 50 ms poll"
        );
    }

    #[test]
    fn depth_reporting_and_high_water() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        assert_eq!(b.push(req(0)), 1);
        assert_eq!(b.push(req(1)), 2);
        assert_eq!(b.depth(), 2);
        let _ = b.next_batch();
        assert_eq!(b.depth(), 0);
        assert_eq!(b.high_water(), 2, "high water survives the drain");
    }

    #[test]
    fn bounded_queue_sheds_then_admits_after_drain() {
        let b = DynamicBatcher::with_capacity(2, Duration::from_millis(5), 3);
        for i in 0..3 {
            assert!(b.try_push(req(i)).is_ok());
        }
        let back = b.try_push(req(3)).unwrap_err();
        assert_eq!(back.id, 3, "shed request returned to the caller");
        let _ = b.next_batch().unwrap(); // drains 2
        assert!(b.try_push(req(4)).is_ok(), "space after drain");
    }

    #[test]
    fn push_wait_blocks_for_space() {
        let b = DynamicBatcher::with_capacity(1, Duration::from_millis(1), 1);
        assert!(b.try_push(req(0)).is_ok());
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.push_wait(req(1)));
        std::thread::sleep(Duration::from_millis(20));
        let _ = b.next_batch().unwrap(); // frees the slot
        assert!(t.join().unwrap().is_ok());
        assert_eq!(b.depth(), 1);
    }

    #[test]
    fn push_wait_bails_on_close() {
        let b = DynamicBatcher::with_capacity(1, Duration::from_millis(1), 1);
        assert!(b.try_push(req(0)).is_ok());
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.push_wait(req(1)));
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        let back = t.join().unwrap().unwrap_err();
        assert_eq!(back.id, 1);
    }

    #[test]
    fn push_after_close_resolves_via_drop_hook() {
        // Regression: the legacy unconditional `push` used to ignore the
        // closed flag, enqueueing into a queue nobody drains — the
        // request's handle never resolved. Now a post-close push hands
        // the request to the drop hook as Shutdown: exactly one outcome.
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        let dropped: Arc<Mutex<Vec<(u64, DropReason)>>> = Arc::new(Mutex::new(Vec::new()));
        let d2 = dropped.clone();
        b.set_drop_hook(Box::new(move |r, why| d2.lock().unwrap().push((r.id, why))));
        b.close();
        b.push(req(9));
        assert_eq!(
            dropped.lock().unwrap().as_slice(),
            &[(9, DropReason::Shutdown)],
            "post-close push resolves through the drop hook"
        );
        assert_eq!(b.depth(), 0, "nothing lingers in the closed queue");
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn expired_requests_dropped_before_dispatch() {
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        let dropped: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let d2 = dropped.clone();
        b.set_drop_hook(Box::new(move |r, why| {
            assert_eq!(why, DropReason::Deadline);
            d2.lock().unwrap().push(r.id);
        }));
        let mut dead = req(0);
        dead.deadline = Some(since_epoch() - 1.0); // already expired
        let live = req(1);
        b.push(dead);
        b.push(live);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1, "expired request never reaches dispatch");
        assert_eq!(dropped.lock().unwrap().as_slice(), &[0]);
    }

    #[test]
    fn all_expired_waits_for_fresh_work() {
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        let mut dead = req(0);
        dead.deadline = Some(since_epoch() - 1.0);
        b.push(dead);
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(30));
        b.push(req(1)); // fresh work arrives after the expired drain
        let batch = t.join().unwrap().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1);
    }

    #[test]
    fn concurrent_pushers_consumer_purger_account_for_every_request() {
        // Brute-force concurrency coverage for the condvar paths PR 4
        // added: three producers race seeded mixes of `try_push` (shed)
        // and `push_wait` (backpressure) — some requests pre-expired so
        // the drop hook fires concurrently with the drain — while one
        // consumer loops `next_batch` and a purger rips queued ids out
        // from under everyone. Three seeds give three interleaving
        // families. The invariant: every request resolves to exactly ONE
        // fate (dispatched, hook-dropped, shed, or purged) — no loss, no
        // duplication, queue empty at the end.
        use crate::util::prng::Rng;
        const PRODUCERS: u64 = 3;
        const PER_PRODUCER: u64 = 120;
        for seed in [1u64, 7, 42] {
            let b = DynamicBatcher::with_capacity(4, Duration::from_millis(1), 8);
            let dropped: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
            let d2 = dropped.clone();
            b.set_drop_hook(Box::new(move |r, _| d2.lock().unwrap().push(r.id)));
            let shed: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
            let producers: Vec<_> = (0..PRODUCERS)
                .map(|p| {
                    let b = b.clone();
                    let shed = shed.clone();
                    std::thread::spawn(move || {
                        let mut rng = Rng::new(seed * 1000 + p);
                        for k in 0..PER_PRODUCER {
                            let mut r = req(p * PER_PRODUCER + k);
                            if rng.chance(0.2) {
                                r.deadline = Some(since_epoch() - 1.0); // pre-expired
                            }
                            let res = if rng.chance(0.5) {
                                b.try_push(r)
                            } else {
                                b.push_wait(r)
                            };
                            if let Err(back) = res {
                                shed.lock().unwrap().push(back.id);
                            }
                            if rng.chance(0.1) {
                                std::thread::yield_now();
                            }
                        }
                    })
                })
                .collect();
            let stop_purge = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let purger = {
                let b = b.clone();
                let stop = stop_purge.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::new(seed ^ 0xBADC0FFE);
                    let mut purged = Vec::new();
                    while !stop.load(Ordering::Relaxed) {
                        let id = rng.below(PRODUCERS * PER_PRODUCER);
                        purged.extend(b.purge(&[id]).into_iter().map(|r| r.id));
                        std::thread::yield_now();
                    }
                    purged
                })
            };
            let consumer = {
                let b = b.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(batch) = b.next_batch() {
                        got.extend(batch.into_iter().map(|r| r.id));
                    }
                    got
                })
            };
            for p in producers {
                p.join().unwrap();
            }
            stop_purge.store(true, Ordering::Relaxed);
            let purged = purger.join().unwrap();
            b.close();
            let dispatched = consumer.join().unwrap();

            let total = (PRODUCERS * PER_PRODUCER) as usize;
            let mut all: Vec<u64> = dispatched;
            all.extend(dropped.lock().unwrap().iter().copied());
            all.extend(shed.lock().unwrap().iter().copied());
            all.extend(purged.iter().copied());
            all.sort_unstable();
            assert_eq!(
                all.len(),
                total,
                "seed {seed}: every request must resolve to exactly one fate"
            );
            all.dedup();
            assert_eq!(all.len(), total, "seed {seed}: no id resolved twice");
            assert_eq!(b.depth(), 0, "seed {seed}: queue drained");
        }
    }

    #[test]
    fn take_ready_fills_free_slots_without_blocking() {
        let b = DynamicBatcher::new(8, Duration::from_secs(10));
        // Empty queue: returns immediately with nothing (the decode loop
        // just runs the residents) — no batch-fill wait.
        let t0 = Instant::now();
        assert!(b.take_ready(4).is_empty());
        assert!(t0.elapsed() < Duration::from_millis(50), "never blocks");
        for i in 0..3 {
            b.push(req(i));
        }
        // Two free slots → exactly two admitted, FIFO; the third stays.
        let got: Vec<u64> = b.take_ready(2).iter().map(|r| r.id).collect();
        assert_eq!(got, vec![0, 1]);
        assert_eq!(b.depth(), 1);
        // Zero free slots is a no-op.
        assert!(b.take_ready(0).is_empty());
        assert_eq!(b.depth(), 1);
    }

    #[test]
    fn continuous_admission_joins_and_leaves_across_steps() {
        // Model the decode loop: a 4-slot running batch where requests
        // retire at different steps and `take_ready` back-fills exactly
        // the freed slots each iteration — requests join and leave the
        // batch mid-flight instead of gang-scheduling.
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        for i in 0..4 {
            b.push(req(i));
        }
        let mut resident: Vec<u64> =
            b.take_ready(4).iter().map(|r| r.id).collect();
        assert_eq!(resident, vec![0, 1, 2, 3]);
        // Step 1: requests 1 and 3 finish; two arrivals land mid-step.
        b.push(req(4));
        b.push(req(5));
        resident.retain(|&id| id != 1 && id != 3);
        let joined: Vec<u64> =
            b.take_ready(4 - resident.len()).iter().map(|r| r.id).collect();
        assert_eq!(joined, vec![4, 5], "arrivals fill freed slots same step");
        resident.extend(joined);
        assert_eq!(resident.len(), 4, "batch stays full across churn");
        // Step 2: nothing queued, one retirement — the loop keeps
        // decoding a partial batch rather than stalling for a fill.
        resident.retain(|&id| id != 0);
        assert!(b.take_ready(4 - resident.len()).is_empty());
        assert_eq!(resident, vec![2, 4, 5]);
    }

    #[test]
    fn head_of_queue_slo_drops_race_slot_admission() {
        // An expired request at the head must be shed by `take_ready`
        // (drop hook, no slot consumed) even while pushers are racing
        // admission — the streaming analogue of
        // `expired_requests_dropped_before_dispatch`.
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        let dropped: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let d2 = dropped.clone();
        b.set_drop_hook(Box::new(move |r, _| d2.lock().unwrap().push(r.id)));
        let mut dead = req(0);
        dead.deadline = Some(since_epoch() - 1.0);
        b.push(dead);
        let b2 = b.clone();
        let racer = std::thread::spawn(move || {
            for i in 1..=8 {
                b2.push(req(i));
                std::thread::yield_now();
            }
        });
        // Keep taking one slot at a time while the racer pushes: the
        // dead head must surface in the drop hook, never in a slot.
        let mut admitted = Vec::new();
        while admitted.len() < 8 {
            admitted.extend(b.take_ready(1).iter().map(|r| r.id));
        }
        racer.join().unwrap();
        assert_eq!(dropped.lock().unwrap().as_slice(), &[0]);
        let mut sorted = admitted.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn shedding_while_running_batch_full() {
        // The running batch is full (take_ready(0) every step), so the
        // bounded queue backs up and admission load-sheds — exactly the
        // saturation regime continuous batching runs in.
        let b = DynamicBatcher::with_capacity(4, Duration::from_millis(1), 2);
        assert!(b.try_push(req(0)).is_ok());
        assert!(b.try_push(req(1)).is_ok());
        let back = b.try_push(req(2)).unwrap_err();
        assert_eq!(back.id, 2, "full queue sheds while the batch is full");
        // Several decode steps pass with no free slots: nothing drains,
        // shedding continues deterministically.
        for _ in 0..3 {
            assert!(b.take_ready(0).is_empty());
            assert!(b.try_push(req(9)).is_err());
        }
        // One retirement frees one slot; one queued request admits and
        // exactly one shed producer gets space back.
        assert_eq!(b.take_ready(1)[0].id, 0);
        assert!(b.try_push(req(3)).is_ok());
        assert!(b.try_push(req(4)).is_err(), "queue full again");
    }

    #[test]
    fn purge_removes_queued_ids() {
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        for i in 0..4 {
            b.push(req(i));
        }
        let purged = b.purge(&[1, 3]);
        assert_eq!(purged.len(), 2);
        assert_eq!(b.depth(), 2);
        let ids: Vec<u64> = b.next_batch().unwrap().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2]);
    }

    #[test]
    fn drr_drains_in_weight_ratio_under_backlog() {
        // 4:1 weights → exactly 4:1 drain under backlog, each tenant
        // internally FIFO. 80+80 queued, 50 slots = 10 full DRR rounds
        // of (4 gold, 1 free) → 40 gold, 10 free.
        let classes = [TenantClass::new("g4", 4, 0), TenantClass::new("f1", 1, 0)];
        let b = DynamicBatcher::with_tenants(8, Duration::from_millis(1), 0, &classes);
        assert!(b.tenant_aware());
        for i in 0..80 {
            b.push(treq(i, "g4"));
            b.push(treq(1000 + i, "f1"));
        }
        let got = b.take_ready(50);
        assert_eq!(got.len(), 50);
        let gold: Vec<u64> =
            got.iter().filter(|r| r.id < 1000).map(|r| r.id).collect();
        let free: Vec<u64> =
            got.iter().filter(|r| r.id >= 1000).map(|r| r.id).collect();
        assert_eq!(gold.len(), 40, "weight-4 tenant gets 4/5 of the slots");
        assert_eq!(free.len(), 10, "weight-1 tenant gets 1/5 of the slots");
        assert_eq!(gold, (0..40).collect::<Vec<_>>(), "per-tenant FIFO holds");
        assert_eq!(free, (1000..1010).collect::<Vec<_>>());
        // The remainder drains completely — DRR starves nobody.
        let rest = b.take_ready(500);
        assert_eq!(rest.len(), 110);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn drr_resumes_mid_visit_and_unknown_tenants_fold_to_default() {
        let classes = [TenantClass::new("vip", 3, 0)];
        let b = DynamicBatcher::with_tenants(8, Duration::from_millis(1), 0, &classes);
        // Unknown tenant + untagged requests share the implicit default
        // class, staying mutually FIFO.
        b.push(treq(0, "mystery"));
        b.push(req(1));
        b.push(treq(2, "vip"));
        b.push(treq(3, "vip"));
        // One slot at a time: the vip visit (quantum 3) is interrupted
        // by output-full and must resume where it stopped, not restart
        // a fresh quantum that would overweight it.
        let mut order = Vec::new();
        for _ in 0..4 {
            order.extend(b.take_ready(1).iter().map(|r| r.id));
        }
        // default was enqueued first → visited first (quantum 1 → one
        // slot), then vip spends its quantum of 3 (only 2 queued), then
        // default again.
        assert_eq!(order, vec![0, 2, 3, 1]);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn per_tenant_bound_sheds_burster_without_touching_others() {
        // The burster exhausts its own depth and sheds; the steady
        // tenant's admission budget is untouched — per-tenant bounds,
        // not a shared global one.
        let classes = [
            TenantClass::new("burst", 1, 3),
            TenantClass::new("steady", 1, 2),
        ];
        let b = DynamicBatcher::with_tenants(8, Duration::from_millis(1), 0, &classes);
        for i in 0..3 {
            assert!(b.try_push(treq(i, "burst")).is_ok());
        }
        assert!(b.try_push(treq(9, "burst")).is_err(), "burster sheds its own");
        assert!(b.try_push(treq(10, "steady")).is_ok(), "other tenant unaffected");
        assert!(b.try_push(treq(11, "steady")).is_ok());
        assert!(b.try_push(treq(12, "steady")).is_err(), "its own bound applies");
        let depths: BTreeMap<String, usize> = b
            .tenant_depths()
            .into_iter()
            .map(|(t, d)| (t.as_str().to_string(), d))
            .collect();
        assert_eq!(depths["burst"], 3);
        assert_eq!(depths["steady"], 2);
        assert_eq!(depths["default"], 0, "implicit class always reported");
    }

    #[test]
    fn depth_gauge_published_under_lock_never_pins_stale() {
        // Regression for the note_depth race: the gauge used to be set
        // *after* the queue lock dropped, so two racing pushes could
        // publish depths out of order and pin the gauge below the real
        // depth. Publishing under the lock makes gauge == depth at every
        // quiescent point. The per-tenant gauge is unique to this test's
        // class name, so parallel tests can't interfere with the
        // assertion.
        let classes = [TenantClass::new("gauge_pin", 1, 0)];
        let b = DynamicBatcher::with_tenants(8, Duration::from_millis(1), 0, &classes);
        let gauge = crate::metrics::global().gauge("serving.queue.depth.tenant.gauge_pin");
        for round in 0..20 {
            let pushers: Vec<_> = (0..4)
                .map(|p| {
                    let b = b.clone();
                    std::thread::spawn(move || {
                        for k in 0..25 {
                            b.push(treq(round * 1000 + p * 100 + k, "gauge_pin"));
                        }
                    })
                })
                .collect();
            for p in pushers {
                p.join().unwrap();
            }
            let depth = b
                .tenant_depths()
                .into_iter()
                .find(|(t, _)| t.as_str() == "gauge_pin")
                .unwrap()
                .1;
            assert_eq!(
                gauge.get(),
                depth as i64,
                "round {round}: gauge coherent after concurrent pushes"
            );
            // Drain some, then re-check: drains publish under the lock
            // too.
            let drained = b.take_ready(60).len();
            assert!(drained > 0);
            let depth = b
                .tenant_depths()
                .into_iter()
                .find(|(t, _)| t.as_str() == "gauge_pin")
                .unwrap()
                .1;
            assert_eq!(gauge.get(), depth as i64, "round {round}: gauge after drain");
            b.take_ready(10_000); // empty it for the next round
        }
    }
}
