//! Pipeline topology: which worlds exist, who is in them, and on which
//! store port each rendezvouses.
//!
//! Per the paper (§3.1, Fig. 2) every pipeline *edge* is its own
//! two-member world:
//!
//! ```text
//!   leader → stage0 replicas          world  in-{0}r{r}
//!   stageᵢ replica a → stageᵢ₊₁ b     world  e{i}r{a}-{i+1}r{b}   (bipartite)
//!   last-stage replica r → leader     world  out-{N-1}r{r}
//! ```
//!
//! The upstream member is always rank 0 (and hosts the per-world store);
//! the downstream member is rank 1. Worlds never span more than one
//! edge, so a worker failure breaks exactly the edges it touches.
//!
//! A topology serializes to JSON so the launcher can hand it to worker
//! processes; generation numbers let online instantiation mint fresh
//! world names for replacement workers (a broken world's name is never
//! reused — CCL worlds are unrecoverable by design).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt;

/// A participant in the serving deployment.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum NodeId {
    Leader,
    Worker { stage: usize, replica: usize },
}

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NodeId::Leader => write!(f, "leader"),
            NodeId::Worker { stage, replica } => write!(f, "s{stage}r{replica}"),
        }
    }
}

impl NodeId {
    pub fn parse(s: &str) -> anyhow::Result<NodeId> {
        if s == "leader" {
            return Ok(NodeId::Leader);
        }
        let rest = s
            .strip_prefix('s')
            .ok_or_else(|| anyhow::anyhow!("bad node id {s:?}"))?;
        let (stage, replica) = rest
            .split_once('r')
            .ok_or_else(|| anyhow::anyhow!("bad node id {s:?}"))?;
        Ok(NodeId::Worker { stage: stage.parse()?, replica: replica.parse()? })
    }
}

/// One two-member world (a pipeline edge).
#[derive(Clone, Debug, PartialEq)]
pub struct WorldDef {
    pub name: String,
    /// members[0] is rank 0 (upstream, hosts the store), members[1] is
    /// rank 1 (downstream).
    pub members: [NodeId; 2],
    pub store_port: u16,
}

impl WorldDef {
    pub fn rank_of(&self, node: NodeId) -> Option<usize> {
        self.members.iter().position(|m| *m == node)
    }

    pub fn peer_of(&self, node: NodeId) -> Option<NodeId> {
        match self.rank_of(node)? {
            0 => Some(self.members[1]),
            _ => Some(self.members[0]),
        }
    }
}

/// The full deployment map.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Topology {
    /// Replicas per stage, e.g. `[1, 2, 1]` is the paper's rhombus.
    pub replicas: Vec<usize>,
    pub worlds: Vec<WorldDef>,
    /// Prefix for world names (namespacing parallel experiments).
    pub prefix: String,
    /// Monotone counter for replacement-world names.
    pub generation: u64,
}

impl Topology {
    /// Build the standard pipeline topology. `base_port` seeds store
    /// ports (world *k* uses `base_port + k`).
    pub fn pipeline(prefix: &str, replicas: &[usize], base_port: u16) -> Topology {
        assert!(!replicas.is_empty());
        assert!(replicas.iter().all(|&r| r >= 1));
        let mut worlds = Vec::new();
        let mut port = base_port;
        let mut push = |name: String, up: NodeId, down: NodeId, port: &mut u16| {
            worlds.push(WorldDef { name, members: [up, down], store_port: *port });
            *port += 1;
        };
        let n = replicas.len();
        // Leader → stage 0.
        for r in 0..replicas[0] {
            push(
                format!("{prefix}-in-s0r{r}"),
                NodeId::Leader,
                NodeId::Worker { stage: 0, replica: r },
                &mut port,
            );
        }
        // Stage i → stage i+1 (full bipartite, one world per pair).
        for i in 0..n - 1 {
            for a in 0..replicas[i] {
                for b in 0..replicas[i + 1] {
                    push(
                        format!("{prefix}-e-s{i}r{a}-s{}r{b}", i + 1),
                        NodeId::Worker { stage: i, replica: a },
                        NodeId::Worker { stage: i + 1, replica: b },
                        &mut port,
                    );
                }
            }
        }
        // Last stage → leader.
        for r in 0..replicas[n - 1] {
            push(
                format!("{prefix}-out-s{}r{r}", n - 1),
                NodeId::Worker { stage: n - 1, replica: r },
                NodeId::Leader,
                &mut port,
            );
        }
        Topology {
            replicas: replicas.to_vec(),
            worlds,
            prefix: prefix.to_string(),
            generation: 0,
        }
    }

    pub fn n_stages(&self) -> usize {
        self.replicas.len()
    }

    /// Worlds `node` participates in.
    pub fn worlds_of(&self, node: NodeId) -> Vec<&WorldDef> {
        self.worlds
            .iter()
            .filter(|w| w.members.contains(&node))
            .collect()
    }

    /// Worlds where `node` is the downstream member (its inputs).
    pub fn in_edges(&self, node: NodeId) -> Vec<&WorldDef> {
        self.worlds
            .iter()
            .filter(|w| w.members[1] == node)
            .collect()
    }

    /// Worlds where `node` is the upstream member (its outputs).
    pub fn out_edges(&self, node: NodeId) -> Vec<&WorldDef> {
        self.worlds
            .iter()
            .filter(|w| w.members[0] == node)
            .collect()
    }

    /// All nodes mentioned in the topology.
    pub fn nodes(&self) -> Vec<NodeId> {
        let mut set: Vec<NodeId> = Vec::new();
        for w in &self.worlds {
            for m in w.members {
                if !set.contains(&m) {
                    set.push(m);
                }
            }
        }
        set.sort();
        set
    }

    /// Worker nodes only.
    pub fn workers(&self) -> Vec<NodeId> {
        self.nodes()
            .into_iter()
            .filter(|n| matches!(n, NodeId::Worker { .. }))
            .collect()
    }

    /// Live replica ids of a stage (derived from world membership —
    /// `replicas[stage]` is an id *allocator* and keeps counting dead
    /// ones).
    pub fn live_replicas(&self, stage: usize) -> Vec<usize> {
        let mut ids: Vec<usize> = self
            .workers()
            .into_iter()
            .filter_map(|n| match n {
                NodeId::Worker { stage: s, replica } if s == stage => Some(replica),
                _ => None,
            })
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Add a replacement/scale-out replica of `stage` with fresh worlds
    /// to every neighbor (the online-instantiation step: "configuring P5
    /// to inherit the exact role of P3"). Returns the new node and the
    /// world definitions that must be initialized.
    pub fn add_replica(
        &mut self,
        stage: usize,
        base_port: u16,
    ) -> (NodeId, Vec<WorldDef>) {
        assert!(stage < self.replicas.len());
        self.generation += 1;
        let gen = self.generation;
        let replica = self.replicas[stage];
        self.replicas[stage] += 1;
        let node = NodeId::Worker { stage, replica };
        let prefix = self.prefix.clone();
        let mut port = base_port;
        let mut fresh = Vec::new();
        let mut push = |name: String, up: NodeId, down: NodeId, port: &mut u16| {
            let def = WorldDef { name, members: [up, down], store_port: *port };
            *port += 1;
            fresh.push(def);
        };
        // Upstream edges — wire to *live* neighbors only (dead replica
        // ids stay burned).
        if stage == 0 {
            push(
                format!("{prefix}-in-s0r{replica}#g{gen}"),
                NodeId::Leader,
                node,
                &mut port,
            );
        } else {
            for a in self.live_replicas(stage - 1) {
                push(
                    format!("{prefix}-e-s{}r{a}-s{stage}r{replica}#g{gen}", stage - 1),
                    NodeId::Worker { stage: stage - 1, replica: a },
                    node,
                    &mut port,
                );
            }
        }
        // Downstream edges.
        if stage == self.replicas.len() - 1 {
            push(format!("{prefix}-out-s{stage}r{replica}#g{gen}"), node, NodeId::Leader, &mut port);
        } else {
            for b in self.live_replicas(stage + 1) {
                push(
                    format!("{prefix}-e-s{stage}r{replica}-s{}r{b}#g{gen}", stage + 1),
                    node,
                    NodeId::Worker { stage: stage + 1, replica: b },
                    &mut port,
                );
            }
        }
        self.worlds.extend(fresh.clone());
        (node, fresh)
    }

    /// Drop every world touching `node` (it died). Returns the removed
    /// world names.
    pub fn remove_node(&mut self, node: NodeId) -> Vec<String> {
        let (dead, keep): (Vec<WorldDef>, Vec<WorldDef>) = self
            .worlds
            .drain(..)
            .partition(|w| w.members.contains(&node));
        self.worlds = keep;
        dead.into_iter().map(|w| w.name).collect()
    }

    // ------------------------------------------------------------- JSON

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("prefix", Json::str(self.prefix.clone())),
            ("generation", Json::num(self.generation as f64)),
            (
                "replicas",
                Json::arr(self.replicas.iter().map(|&r| Json::num(r as f64)).collect()),
            ),
            (
                "worlds",
                Json::arr(
                    self.worlds
                        .iter()
                        .map(|w| {
                            Json::obj(vec![
                                ("name", Json::str(w.name.clone())),
                                ("up", Json::str(w.members[0].to_string())),
                                ("down", Json::str(w.members[1].to_string())),
                                ("store_port", Json::num(w.store_port as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Topology> {
        let prefix = j
            .get("prefix")
            .and_then(|v| v.as_str())
            .unwrap_or("mw")
            .to_string();
        let generation = j.get("generation").and_then(|v| v.as_i64()).unwrap_or(0) as u64;
        let replicas = j
            .get("replicas")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default();
        let mut worlds = Vec::new();
        for w in j
            .get("worlds")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("topology missing worlds"))?
        {
            let name = w
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("world missing name"))?
                .to_string();
            let up = NodeId::parse(w.get("up").and_then(|v| v.as_str()).unwrap_or(""))?;
            let down = NodeId::parse(w.get("down").and_then(|v| v.as_str()).unwrap_or(""))?;
            let store_port = w
                .get("store_port")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("world missing store_port"))? as u16;
            worlds.push(WorldDef { name, members: [up, down], store_port });
        }
        Ok(Topology { replicas, worlds, prefix, generation })
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Topology> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Map stage→replica-count as a compact string ("1x2x1").
    pub fn shape(&self) -> String {
        self.replicas
            .iter()
            .map(|r| r.to_string())
            .collect::<Vec<_>>()
            .join("x")
    }

    /// Per-stage count of live edge worlds, for diagnostics.
    pub fn edge_counts(&self) -> BTreeMap<usize, usize> {
        let mut m = BTreeMap::new();
        for w in &self.worlds {
            if let NodeId::Worker { stage, .. } = w.members[0] {
                *m.entry(stage).or_insert(0) += 1;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rhombus_topology_matches_paper() {
        // Fig. 2a: stages [1, 2, 1] ⇒ P1 feeds P2 and P3, both feed P4.
        let t = Topology::pipeline("mw", &[1, 2, 1], 20_000);
        // Worlds: 1 in + (1×2) + (2×1) + 1 out = 6.
        assert_eq!(t.worlds.len(), 6);
        let p1 = NodeId::Worker { stage: 0, replica: 0 };
        let p4 = NodeId::Worker { stage: 2, replica: 0 };
        assert_eq!(t.out_edges(p1).len(), 2, "P1 feeds both middle replicas");
        assert_eq!(t.in_edges(p4).len(), 2, "P4 hears from both middle replicas");
        assert_eq!(t.in_edges(NodeId::Leader).len(), 1);
        assert_eq!(t.workers().len(), 4);
    }

    #[test]
    fn node_id_roundtrip() {
        for n in [NodeId::Leader, NodeId::Worker { stage: 3, replica: 7 }] {
            assert_eq!(NodeId::parse(&n.to_string()).unwrap(), n);
        }
        assert!(NodeId::parse("bogus").is_err());
    }

    #[test]
    fn store_ports_unique() {
        let t = Topology::pipeline("mw", &[2, 3, 2], 21_000);
        let mut ports: Vec<u16> = t.worlds.iter().map(|w| w.store_port).collect();
        ports.sort_unstable();
        ports.dedup();
        assert_eq!(ports.len(), t.worlds.len());
    }

    #[test]
    fn ranks_follow_upstream_downstream() {
        let t = Topology::pipeline("mw", &[1, 1], 22_000);
        for w in &t.worlds {
            assert_eq!(w.rank_of(w.members[0]), Some(0));
            assert_eq!(w.rank_of(w.members[1]), Some(1));
            assert_eq!(w.peer_of(w.members[0]), Some(w.members[1]));
        }
    }

    #[test]
    fn json_roundtrip() {
        let t = Topology::pipeline("exp1", &[1, 2, 1], 23_000);
        let j = t.to_json();
        let back = Topology::from_json(&j).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn add_replica_connects_both_sides_with_fresh_names() {
        let mut t = Topology::pipeline("mw", &[1, 2, 1], 24_000);
        let before = t.worlds.len();
        let (node, fresh) = t.add_replica(1, 25_000);
        assert_eq!(node, NodeId::Worker { stage: 1, replica: 2 });
        // New middle replica: 1 upstream (from s0r0) + 1 downstream (to s2r0).
        assert_eq!(fresh.len(), 2);
        assert!(fresh.iter().all(|w| w.name.contains("#g1")), "generation-tagged");
        assert_eq!(t.worlds.len(), before + 2);
        assert_eq!(t.replicas, vec![1, 3, 1]);
    }

    #[test]
    fn add_replica_first_and_last_stage_touch_leader() {
        let mut t = Topology::pipeline("mw", &[1, 1], 26_000);
        let (_, fresh0) = t.add_replica(0, 27_000);
        assert!(fresh0.iter().any(|w| w.members[0] == NodeId::Leader));
        let (_, fresh1) = t.add_replica(1, 28_000);
        assert!(fresh1.iter().any(|w| w.members[1] == NodeId::Leader));
    }

    #[test]
    fn remove_node_drops_exactly_its_worlds() {
        let mut t = Topology::pipeline("mw", &[1, 2, 1], 29_000);
        let p3 = NodeId::Worker { stage: 1, replica: 1 };
        let dead = t.remove_node(p3);
        // P3 touched two worlds (from P1, to P4) — Fig. 2b.
        assert_eq!(dead.len(), 2);
        assert_eq!(t.worlds.len(), 4);
        assert!(t.worlds_of(p3).is_empty());
        // P2's worlds intact.
        let p2 = NodeId::Worker { stage: 1, replica: 0 };
        assert_eq!(t.worlds_of(p2).len(), 2);
    }

    #[test]
    fn shape_string() {
        assert_eq!(Topology::pipeline("x", &[1, 2, 1], 30_000).shape(), "1x2x1");
    }
}
