//! Pipeline topology: which worlds exist, who is in them, and on which
//! store port each rendezvouses.
//!
//! Per the paper (§3.1, Fig. 2) every pipeline *edge* is its own
//! two-member world; since the sharding refactor every replica may
//! additionally be split into `tp` tensor-parallel **shards** joined by
//! one multi-member intra-replica world:
//!
//! ```text
//!   leader → stage0 replicas          world  in-s0r{r}
//!   stageᵢ replica a → stageᵢ₊₁ b     world  e-s{i}r{a}-s{i+1}r{b}   (bipartite)
//!   last-stage replica r → leader     world  out-s{N-1}r{r}
//!   shards of stageᵢ replica r        world  tp-s{i}r{r}             (tp members)
//! ```
//!
//! **Naming scheme.** A worker node is `s{stage}r{replica}t{shard}`;
//! shard 0 — the replica's *head*, the only shard that sits on edge
//! worlds — omits the `t` suffix, so a `tp = 1` deployment is spelled
//! exactly like the pre-sharding `s{stage}r{replica}` scheme and its
//! world names and members are byte-identical to it. Edge worlds always
//! terminate at heads; the upstream member is rank 0 (and hosts the
//! per-world store), the downstream member is rank 1. A TP world
//! `tp-s{stage}r{replica}` contains the replica's shards in shard order
//! (rank == shard), so the head hosts its store.
//!
//! Worlds never span more than one edge or one replica, so a worker
//! failure breaks exactly the worlds it touches: a dead head breaks its
//! replica's TP world and its edge worlds; a dead non-head shard breaks
//! only the TP world (shard-granularity fault domains).
//!
//! A topology serializes to JSON so the launcher can hand it to worker
//! processes; generation numbers let online instantiation mint fresh
//! world names for replacement workers (a broken world's name is never
//! reused — CCL worlds are unrecoverable by design). Shard recovery
//! keeps the replica and shard ids and re-mints only the *worlds*
//! (see [`Topology::remint_replica`]).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt;

/// A participant in the serving deployment.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum NodeId {
    Leader,
    Worker { stage: usize, replica: usize, shard: usize },
}

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NodeId::Leader => write!(f, "leader"),
            NodeId::Worker { stage, replica, shard: 0 } => write!(f, "s{stage}r{replica}"),
            NodeId::Worker { stage, replica, shard } => {
                write!(f, "s{stage}r{replica}t{shard}")
            }
        }
    }
}

impl NodeId {
    /// A replica's head (shard 0) — the only shard on edge worlds.
    pub fn worker(stage: usize, replica: usize) -> NodeId {
        NodeId::Worker { stage, replica, shard: 0 }
    }

    /// The head shard of this worker's replica (identity for heads).
    pub fn head(self) -> NodeId {
        match self {
            NodeId::Leader => NodeId::Leader,
            NodeId::Worker { stage, replica, .. } => NodeId::Worker { stage, replica, shard: 0 },
        }
    }

    /// True for shard 0 of a replica (and for the leader).
    pub fn is_head(self) -> bool {
        !matches!(self, NodeId::Worker { shard, .. } if shard != 0)
    }

    /// True when this is a worker shard of `(stage, replica)` — the
    /// single definition of replica membership (kill/shutdown/world
    /// removal all filter with it).
    pub fn in_replica(self, stage: usize, replica: usize) -> bool {
        matches!(self, NodeId::Worker { stage: s, replica: r, .. }
            if s == stage && r == replica)
    }

    pub fn parse(s: &str) -> anyhow::Result<NodeId> {
        if s == "leader" {
            return Ok(NodeId::Leader);
        }
        let rest = s
            .strip_prefix('s')
            .ok_or_else(|| anyhow::anyhow!("bad node id {s:?}"))?;
        let (stage, rest) = rest
            .split_once('r')
            .ok_or_else(|| anyhow::anyhow!("bad node id {s:?}"))?;
        let (replica, shard) = match rest.split_once('t') {
            Some((r, t)) => (r, t.parse()?),
            None => (rest, 0),
        };
        Ok(NodeId::Worker { stage: stage.parse()?, replica: replica.parse()?, shard })
    }
}

/// What a world is for: a pipeline edge (always two members) or an
/// intra-replica tensor-parallel group (`tp` members, rank == shard).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorldKind {
    Edge,
    Tp,
}

impl WorldKind {
    fn name(self) -> &'static str {
        match self {
            WorldKind::Edge => "edge",
            WorldKind::Tp => "tp",
        }
    }

    fn parse(s: &str) -> anyhow::Result<WorldKind> {
        match s {
            "edge" => Ok(WorldKind::Edge),
            "tp" => Ok(WorldKind::Tp),
            other => anyhow::bail!("bad world kind {other:?}"),
        }
    }
}

/// One world: a two-member pipeline edge or a multi-member TP group.
#[derive(Clone, Debug, PartialEq)]
pub struct WorldDef {
    pub name: String,
    /// Rank `i` is `members[i]`. Edges: `[upstream, downstream]` (rank 0
    /// hosts the store). TP worlds: the replica's shards in shard order.
    pub members: Vec<NodeId>,
    pub store_port: u16,
    pub kind: WorldKind,
}

impl WorldDef {
    /// A two-member edge world (upstream hosts the store).
    pub fn edge(name: String, up: NodeId, down: NodeId, store_port: u16) -> WorldDef {
        WorldDef { name, members: vec![up, down], store_port, kind: WorldKind::Edge }
    }

    pub fn size(&self) -> usize {
        self.members.len()
    }

    pub fn is_tp(&self) -> bool {
        self.kind == WorldKind::Tp
    }

    pub fn rank_of(&self, node: NodeId) -> Option<usize> {
        self.members.iter().position(|m| *m == node)
    }

    /// The other member of a two-member world (`None` on TP worlds with
    /// more than two members — there is no single peer).
    pub fn peer_of(&self, node: NodeId) -> Option<NodeId> {
        if self.members.len() != 2 {
            return None;
        }
        match self.rank_of(node)? {
            0 => Some(self.members[1]),
            _ => Some(self.members[0]),
        }
    }

    pub fn to_json(&self) -> Json {
        world_to_json(self)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<WorldDef> {
        world_from_json(j)
    }
}

/// The full deployment map.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Topology {
    /// Replicas per stage, e.g. `[1, 2, 1]` is the paper's rhombus.
    pub replicas: Vec<usize>,
    /// Tensor-parallel shards per replica, per stage (`1` = unsharded).
    pub tp: Vec<usize>,
    pub worlds: Vec<WorldDef>,
    /// Prefix for world names (namespacing parallel experiments).
    pub prefix: String,
    /// Monotone counter for replacement-world names.
    pub generation: u64,
    /// Host placement: node → host id. Empty (the default) means
    /// everything is co-located — the historical single-host behavior.
    /// A placed topology derives a per-world `MW_HOSTMAP` spec from it
    /// (see [`Topology::world_hostmap`]) so every world a node joins
    /// carries the same locality picture into the collective selector
    /// and the mux transport. Nodes absent from the map sit on host 0.
    pub hosts: BTreeMap<NodeId, usize>,
}

impl Topology {
    /// Build the standard (unsharded) pipeline topology. `base_port`
    /// seeds store ports (world *k* uses `base_port + k`).
    pub fn pipeline(prefix: &str, replicas: &[usize], base_port: u16) -> Topology {
        Self::pipeline_tp(prefix, replicas, &vec![1; replicas.len()], base_port)
    }

    /// Build a pipeline whose stage-`i` replicas are split into `tp[i]`
    /// tensor-parallel shards each. Edge worlds (and their names, member
    /// lists and port order) are identical to [`Topology::pipeline`] —
    /// they terminate at replica heads — and one `tp-s{i}r{r}` world per
    /// sharded replica is appended after them.
    pub fn pipeline_tp(
        prefix: &str,
        replicas: &[usize],
        tp: &[usize],
        base_port: u16,
    ) -> Topology {
        assert!(!replicas.is_empty());
        assert_eq!(replicas.len(), tp.len(), "one tp degree per stage");
        assert!(replicas.iter().all(|&r| r >= 1));
        assert!(tp.iter().all(|&t| t >= 1));
        let mut worlds = Vec::new();
        let mut port = base_port;
        let mut push = |name: String, up: NodeId, down: NodeId, port: &mut u16| {
            worlds.push(WorldDef::edge(name, up, down, *port));
            *port += 1;
        };
        let n = replicas.len();
        // Leader → stage 0.
        for r in 0..replicas[0] {
            push(
                format!("{prefix}-in-s0r{r}"),
                NodeId::Leader,
                NodeId::worker(0, r),
                &mut port,
            );
        }
        // Stage i → stage i+1 (full bipartite, one world per pair).
        for i in 0..n - 1 {
            for a in 0..replicas[i] {
                for b in 0..replicas[i + 1] {
                    push(
                        format!("{prefix}-e-s{i}r{a}-s{}r{b}", i + 1),
                        NodeId::worker(i, a),
                        NodeId::worker(i + 1, b),
                        &mut port,
                    );
                }
            }
        }
        // Last stage → leader.
        for r in 0..replicas[n - 1] {
            push(
                format!("{prefix}-out-s{}r{r}", n - 1),
                NodeId::worker(n - 1, r),
                NodeId::Leader,
                &mut port,
            );
        }
        // Intra-replica TP worlds (after the edges so a tp = 1 topology
        // is byte-identical to the pre-sharding one, ports included).
        for (i, (&reps, &t)) in replicas.iter().zip(tp).enumerate() {
            if t < 2 {
                continue;
            }
            for r in 0..reps {
                worlds.push(tp_world_def(prefix, i, r, t, port, None));
                port += 1;
            }
        }
        Topology {
            replicas: replicas.to_vec(),
            tp: tp.to_vec(),
            worlds,
            prefix: prefix.to_string(),
            generation: 0,
            hosts: BTreeMap::new(),
        }
    }

    // ------------------------------------------------------- placement

    /// Place `node` on `host`. Raw host ids are free-form; each world's
    /// derived spec renumbers them densely (see
    /// [`crate::mwccl::HostMap`]).
    pub fn assign_host(&mut self, node: NodeId, host: usize) {
        self.hosts.insert(node, host);
    }

    /// Bulk placement: the leader on host 0 and every replica — all its
    /// shards together — round-robin over `n_hosts` hosts in `(stage,
    /// replica)` order. Models the common "one replica per machine"
    /// deployment, under which TP worlds stay intra-host while pipeline
    /// edges cross hosts.
    pub fn place_replicas(&mut self, n_hosts: usize) {
        assert!(n_hosts >= 1);
        self.hosts.insert(NodeId::Leader, 0);
        let mut group = 0usize;
        for stage in 0..self.n_stages() {
            for replica in self.live_replicas(stage) {
                let host = group % n_hosts;
                for shard in self.shards_of(stage, replica) {
                    self.hosts.insert(shard, host);
                }
                group += 1;
            }
        }
    }

    /// Host of `node` (0 when unplaced — co-located by default).
    pub fn host_of(&self, node: NodeId) -> usize {
        self.hosts.get(&node).copied().unwrap_or(0)
    }

    /// The `MW_HOSTMAP` comma-list spec for `w`, aligned with its rank
    /// order (`spec[i]` is `members[i]`'s host). `None` when the
    /// topology is unplaced or all members share a host — the world
    /// then runs with the plain single-host default and no entry needs
    /// to be threaded into its `WorldOptions`.
    pub fn world_hostmap(&self, w: &WorldDef) -> Option<String> {
        if self.hosts.is_empty() {
            return None;
        }
        let ids: Vec<usize> = w.members.iter().map(|&m| self.host_of(m)).collect();
        if ids.iter().all(|&h| h == ids[0]) {
            return None;
        }
        Some(ids.iter().map(|h| h.to_string()).collect::<Vec<_>>().join(","))
    }

    pub fn n_stages(&self) -> usize {
        self.replicas.len()
    }

    /// Shards per replica of `stage` (1 when the stage is unsharded or
    /// the topology predates sharding).
    pub fn tp_of(&self, stage: usize) -> usize {
        self.tp.get(stage).copied().unwrap_or(1)
    }

    /// Worlds `node` participates in.
    pub fn worlds_of(&self, node: NodeId) -> Vec<&WorldDef> {
        self.worlds
            .iter()
            .filter(|w| w.members.contains(&node))
            .collect()
    }

    /// Edge worlds where `node` is the downstream member (its inputs).
    pub fn in_edges(&self, node: NodeId) -> Vec<&WorldDef> {
        self.worlds
            .iter()
            .filter(|w| w.kind == WorldKind::Edge && w.members[1] == node)
            .collect()
    }

    /// Edge worlds where `node` is the upstream member (its outputs).
    pub fn out_edges(&self, node: NodeId) -> Vec<&WorldDef> {
        self.worlds
            .iter()
            .filter(|w| w.kind == WorldKind::Edge && w.members[0] == node)
            .collect()
    }

    /// The intra-replica TP world `node` belongs to, if its replica is
    /// sharded.
    pub fn tp_world_of(&self, node: NodeId) -> Option<&WorldDef> {
        self.worlds
            .iter()
            .find(|w| w.kind == WorldKind::Tp && w.members.contains(&node))
    }

    /// All nodes mentioned in the topology.
    pub fn nodes(&self) -> Vec<NodeId> {
        let mut set: Vec<NodeId> = Vec::new();
        for w in &self.worlds {
            for &m in &w.members {
                if !set.contains(&m) {
                    set.push(m);
                }
            }
        }
        set.sort();
        set
    }

    /// Worker nodes only (every shard of every replica).
    pub fn workers(&self) -> Vec<NodeId> {
        self.nodes()
            .into_iter()
            .filter(|n| matches!(n, NodeId::Worker { .. }))
            .collect()
    }

    /// Live replica ids of a stage (derived from world membership —
    /// `replicas[stage]` is an id *allocator* and keeps counting dead
    /// ones).
    pub fn live_replicas(&self, stage: usize) -> Vec<usize> {
        let mut ids: Vec<usize> = self
            .workers()
            .into_iter()
            .filter_map(|n| match n {
                NodeId::Worker { stage: s, replica, .. } if s == stage => Some(replica),
                _ => None,
            })
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Add a replacement/scale-out replica of `stage` — `tp[stage]`
    /// shards joined by a fresh TP world, with fresh edge worlds from
    /// the head to every live neighbor head (the online-instantiation
    /// step: "configuring P5 to inherit the exact role of P3"). Returns
    /// the new head node and the world definitions that must be
    /// initialized.
    pub fn add_replica(
        &mut self,
        stage: usize,
        base_port: u16,
    ) -> (NodeId, Vec<WorldDef>) {
        assert!(stage < self.replicas.len());
        self.generation += 1;
        let gen = self.generation;
        let replica = self.replicas[stage];
        self.replicas[stage] += 1;
        let node = NodeId::worker(stage, replica);
        let mut port = base_port;
        let mut fresh = self.replica_edges(stage, replica, gen, &mut port);
        let tp = self.tp_of(stage);
        if tp > 1 {
            fresh.push(tp_world_def(&self.prefix, stage, replica, tp, port, Some(gen)));
        }
        self.worlds.extend(fresh.clone());
        (node, fresh)
    }

    /// Shard-granularity recovery: drop every world of replica
    /// `(stage, replica)` that `dead_shard`'s death broke — the TP world
    /// always, the head's edge worlds when the head died — and mint
    /// fresh, generation-tagged replacements with the same members.
    /// Healthy worlds (a surviving head's edges) are left untouched.
    /// Returns `(removed world names, fresh world defs)`.
    pub fn remint_replica(
        &mut self,
        dead_shard: NodeId,
        base_port: u16,
    ) -> (Vec<String>, Vec<WorldDef>) {
        let NodeId::Worker { stage, replica, shard } = dead_shard else {
            return (Vec::new(), Vec::new());
        };
        self.generation += 1;
        let gen = self.generation;
        let head = dead_shard.head();
        let tp = self.tp_of(stage);
        // Broken set: the TP world, plus the head's edges if it died.
        let (dead, keep): (Vec<WorldDef>, Vec<WorldDef>) =
            self.worlds.drain(..).partition(|w| {
                (w.kind == WorldKind::Tp && w.members.contains(&head))
                    || (shard == 0 && w.members.contains(&head))
            });
        self.worlds = keep;
        let mut port = base_port;
        let mut fresh = Vec::new();
        if shard == 0 {
            fresh = self.replica_edges(stage, replica, gen, &mut port);
        }
        if tp > 1 {
            fresh.push(tp_world_def(&self.prefix, stage, replica, tp, port, Some(gen)));
        }
        self.worlds.extend(fresh.clone());
        (dead.into_iter().map(|w| w.name).collect(), fresh)
    }

    /// Fresh generation-tagged edge worlds wiring `(stage, replica)`'s
    /// head to every *live* neighbor head (dead replica ids stay
    /// burned).
    fn replica_edges(
        &self,
        stage: usize,
        replica: usize,
        gen: u64,
        port: &mut u16,
    ) -> Vec<WorldDef> {
        let node = NodeId::worker(stage, replica);
        let prefix = &self.prefix;
        let mut fresh = Vec::new();
        let mut push = |name: String, up: NodeId, down: NodeId, port: &mut u16| {
            fresh.push(WorldDef::edge(name, up, down, *port));
            *port += 1;
        };
        // Upstream edges.
        if stage == 0 {
            push(
                format!("{prefix}-in-s0r{replica}#g{gen}"),
                NodeId::Leader,
                node,
                port,
            );
        } else {
            for a in self.live_replicas(stage - 1) {
                push(
                    format!("{prefix}-e-s{}r{a}-s{stage}r{replica}#g{gen}", stage - 1),
                    NodeId::worker(stage - 1, a),
                    node,
                    port,
                );
            }
        }
        // Downstream edges.
        if stage == self.replicas.len() - 1 {
            push(format!("{prefix}-out-s{stage}r{replica}#g{gen}"), node, NodeId::Leader, port);
        } else {
            for b in self.live_replicas(stage + 1) {
                push(
                    format!("{prefix}-e-s{stage}r{replica}-s{}r{b}#g{gen}", stage + 1),
                    node,
                    NodeId::worker(stage + 1, b),
                    port,
                );
            }
        }
        fresh
    }

    /// Drop every world touching `node` (it died). For a head this is
    /// its edge worlds and its replica's TP world; for a non-head shard
    /// only the TP world. Returns the removed world names.
    pub fn remove_node(&mut self, node: NodeId) -> Vec<String> {
        let (dead, keep): (Vec<WorldDef>, Vec<WorldDef>) = self
            .worlds
            .drain(..)
            .partition(|w| w.members.contains(&node));
        self.worlds = keep;
        self.hosts.remove(&node);
        dead.into_iter().map(|w| w.name).collect()
    }

    /// Worker shards of `(stage, replica)` present in the topology.
    pub fn shards_of(&self, stage: usize, replica: usize) -> Vec<NodeId> {
        self.workers()
            .into_iter()
            .filter(|n| n.in_replica(stage, replica))
            .collect()
    }

    /// Drop every world of every shard of `(stage, replica)`. Returns
    /// the removed world names.
    pub fn remove_replica(&mut self, stage: usize, replica: usize) -> Vec<String> {
        let (dead, keep): (Vec<WorldDef>, Vec<WorldDef>) =
            self.worlds.drain(..).partition(|w| {
                w.members.iter().any(|m| m.in_replica(stage, replica))
            });
        self.worlds = keep;
        self.hosts.retain(|n, _| !n.in_replica(stage, replica));
        dead.into_iter().map(|w| w.name).collect()
    }

    // ------------------------------------------------------------- JSON

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("prefix", Json::str(self.prefix.clone())),
            ("generation", Json::num(self.generation as f64)),
            (
                "replicas",
                Json::arr(self.replicas.iter().map(|&r| Json::num(r as f64)).collect()),
            ),
            (
                "tp",
                Json::arr(self.tp.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
            (
                "worlds",
                Json::arr(self.worlds.iter().map(world_to_json).collect()),
            ),
        ];
        // Omitted when unplaced, so pre-placement dumps stay byte-identical.
        if !self.hosts.is_empty() {
            pairs.push((
                "hosts",
                Json::Obj(
                    self.hosts
                        .iter()
                        .map(|(n, &h)| (n.to_string(), Json::num(h as f64)))
                        .collect(),
                ),
            ));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Topology> {
        let prefix = j
            .get("prefix")
            .and_then(|v| v.as_str())
            .unwrap_or("mw")
            .to_string();
        let generation = j.get("generation").and_then(|v| v.as_i64()).unwrap_or(0) as u64;
        let replicas: Vec<usize> = j
            .get("replicas")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default();
        let tp: Vec<usize> = j
            .get("tp")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_else(|| vec![1; replicas.len()]);
        let mut worlds = Vec::new();
        for w in j
            .get("worlds")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("topology missing worlds"))?
        {
            worlds.push(world_from_json(w)?);
        }
        let mut hosts = BTreeMap::new();
        if let Some(m) = j.get("hosts").and_then(|v| v.as_obj()) {
            for (k, v) in m {
                let host = v
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("bad host id for node {k}"))?;
                hosts.insert(NodeId::parse(k)?, host);
            }
        }
        Ok(Topology { replicas, tp, worlds, prefix, generation, hosts })
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Topology> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Map stage→replica-count as a compact string ("1x2x1"; a sharded
    /// stage is annotated with its tp degree, e.g. "1x2:t2x1").
    pub fn shape(&self) -> String {
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let tp = self.tp_of(i);
                if tp > 1 {
                    format!("{r}:t{tp}")
                } else {
                    r.to_string()
                }
            })
            .collect::<Vec<_>>()
            .join("x")
    }

    /// Per-stage count of live edge worlds, for diagnostics.
    pub fn edge_counts(&self) -> BTreeMap<usize, usize> {
        let mut m = BTreeMap::new();
        for w in &self.worlds {
            if w.kind != WorldKind::Edge {
                continue;
            }
            if let NodeId::Worker { stage, .. } = w.members[0] {
                *m.entry(stage).or_insert(0) += 1;
            }
        }
        m
    }
}

/// The intra-replica TP world of `(stage, replica)`: members are the
/// replica's shards in shard order (rank == shard, head hosts the
/// store). `gen` tags replacement worlds minted after a shard death.
fn tp_world_def(
    prefix: &str,
    stage: usize,
    replica: usize,
    tp: usize,
    store_port: u16,
    gen: Option<u64>,
) -> WorldDef {
    let suffix = gen.map(|g| format!("#g{g}")).unwrap_or_default();
    WorldDef {
        name: format!("{prefix}-tp-s{stage}r{replica}{suffix}"),
        members: (0..tp)
            .map(|shard| NodeId::Worker { stage, replica, shard })
            .collect(),
        store_port,
        kind: WorldKind::Tp,
    }
}

fn world_to_json(w: &WorldDef) -> Json {
    Json::obj(vec![
        ("name", Json::str(w.name.clone())),
        ("kind", Json::str(w.kind.name().to_string())),
        (
            "members",
            Json::arr(w.members.iter().map(|m| Json::str(m.to_string())).collect()),
        ),
        ("store_port", Json::num(w.store_port as f64)),
    ])
}

fn world_from_json(w: &Json) -> anyhow::Result<WorldDef> {
    let name = w
        .get("name")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow::anyhow!("world missing name"))?
        .to_string();
    let kind = match w.get("kind").and_then(|v| v.as_str()) {
        Some(k) => WorldKind::parse(k)?,
        None => WorldKind::Edge,
    };
    let members: Vec<NodeId> = match w.get("members").and_then(|v| v.as_arr()) {
        Some(a) => a
            .iter()
            .map(|m| {
                NodeId::parse(m.as_str().ok_or_else(|| anyhow::anyhow!("bad member"))?)
            })
            .collect::<anyhow::Result<_>>()?,
        // Pre-sharding format: separate up/down fields.
        None => vec![
            NodeId::parse(w.get("up").and_then(|v| v.as_str()).unwrap_or(""))?,
            NodeId::parse(w.get("down").and_then(|v| v.as_str()).unwrap_or(""))?,
        ],
    };
    anyhow::ensure!(members.len() >= 2, "world {name} needs ≥2 members");
    let store_port = w
        .get("store_port")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow::anyhow!("world missing store_port"))? as u16;
    Ok(WorldDef { name, members, store_port, kind })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rhombus_topology_matches_paper() {
        // Fig. 2a: stages [1, 2, 1] ⇒ P1 feeds P2 and P3, both feed P4.
        let t = Topology::pipeline("mw", &[1, 2, 1], 20_000);
        // Worlds: 1 in + (1×2) + (2×1) + 1 out = 6.
        assert_eq!(t.worlds.len(), 6);
        let p1 = NodeId::worker(0, 0);
        let p4 = NodeId::worker(2, 0);
        assert_eq!(t.out_edges(p1).len(), 2, "P1 feeds both middle replicas");
        assert_eq!(t.in_edges(p4).len(), 2, "P4 hears from both middle replicas");
        assert_eq!(t.in_edges(NodeId::Leader).len(), 1);
        assert_eq!(t.workers().len(), 4);
    }

    #[test]
    fn node_id_roundtrip() {
        for n in [
            NodeId::Leader,
            NodeId::worker(3, 7),
            NodeId::Worker { stage: 1, replica: 2, shard: 3 },
        ] {
            assert_eq!(NodeId::parse(&n.to_string()).unwrap(), n);
        }
        // Shard 0 omits the t suffix but the explicit form still parses.
        assert_eq!(NodeId::worker(1, 2).to_string(), "s1r2");
        assert_eq!(NodeId::parse("s1r2t0").unwrap(), NodeId::worker(1, 2));
        assert!(NodeId::parse("bogus").is_err());
    }

    #[test]
    fn store_ports_unique() {
        let t = Topology::pipeline_tp("mw", &[2, 3, 2], &[2, 1, 3], 21_000);
        let mut ports: Vec<u16> = t.worlds.iter().map(|w| w.store_port).collect();
        ports.sort_unstable();
        ports.dedup();
        assert_eq!(ports.len(), t.worlds.len());
    }

    #[test]
    fn ranks_follow_upstream_downstream() {
        let t = Topology::pipeline("mw", &[1, 1], 22_000);
        for w in &t.worlds {
            assert_eq!(w.rank_of(w.members[0]), Some(0));
            assert_eq!(w.rank_of(w.members[1]), Some(1));
            assert_eq!(w.peer_of(w.members[0]), Some(w.members[1]));
        }
    }

    #[test]
    fn json_roundtrip() {
        for t in [
            Topology::pipeline("exp1", &[1, 2, 1], 23_000),
            Topology::pipeline_tp("exp2", &[1, 2], &[2, 3], 23_100),
        ] {
            let back = Topology::from_json(&t.to_json()).unwrap();
            assert_eq!(back, t);
        }
    }

    #[test]
    fn tp1_topology_is_byte_identical_to_unsharded() {
        let plain = Topology::pipeline("mw", &[1, 2, 1], 20_500);
        let tp1 = Topology::pipeline_tp("mw", &[1, 2, 1], &[1, 1, 1], 20_500);
        assert_eq!(plain, tp1);
        for (a, b) in plain.worlds.iter().zip(&tp1.worlds) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.members, b.members);
            assert_eq!(a.store_port, b.store_port);
        }
    }

    #[test]
    fn tp_worlds_join_shards_in_rank_order() {
        let t = Topology::pipeline_tp("mw", &[1, 2], &[2, 3], 24_500);
        // Edges unchanged: 1 in + 1×2 bipartite + 2 out = 5; TP worlds:
        // 1 (stage 0) + 2 (stage 1) = 3.
        assert_eq!(t.worlds.len(), 5 + 3);
        let tp0 = t.tp_world_of(NodeId::worker(0, 0)).unwrap();
        assert_eq!(tp0.name, "mw-tp-s0r0");
        assert_eq!(tp0.size(), 2);
        assert_eq!(tp0.members[0], NodeId::worker(0, 0), "head is rank 0");
        let s1r1t2 = NodeId::Worker { stage: 1, replica: 1, shard: 2 };
        let tp11 = t.tp_world_of(s1r1t2).unwrap();
        assert_eq!(tp11.name, "mw-tp-s1r1");
        assert_eq!(tp11.rank_of(s1r1t2), Some(2), "rank == shard");
        // Non-head shards sit on no edges; every edge terminates at heads.
        assert!(t.in_edges(s1r1t2).is_empty() && t.out_edges(s1r1t2).is_empty());
        assert_eq!(t.workers().len(), 2 + 2 * 3);
        // Shape annotates sharded stages.
        assert_eq!(t.shape(), "1:t2x2:t3");
    }

    #[test]
    fn add_replica_connects_both_sides_with_fresh_names() {
        let mut t = Topology::pipeline("mw", &[1, 2, 1], 24_000);
        let before = t.worlds.len();
        let (node, fresh) = t.add_replica(1, 25_000);
        assert_eq!(node, NodeId::worker(1, 2));
        // New middle replica: 1 upstream (from s0r0) + 1 downstream (to s2r0).
        assert_eq!(fresh.len(), 2);
        assert!(fresh.iter().all(|w| w.name.contains("#g1")), "generation-tagged");
        assert_eq!(t.worlds.len(), before + 2);
        assert_eq!(t.replicas, vec![1, 3, 1]);
    }

    #[test]
    fn add_replica_of_sharded_stage_mints_tp_world() {
        let mut t = Topology::pipeline_tp("mw", &[1, 1], &[1, 2], 26_500);
        let (node, fresh) = t.add_replica(1, 27_500);
        assert_eq!(node, NodeId::worker(1, 1));
        // 1 upstream edge + 1 downstream edge + 1 TP world.
        assert_eq!(fresh.len(), 3);
        let tp = fresh.iter().find(|w| w.is_tp()).unwrap();
        assert_eq!(tp.name, "mw-tp-s1r1#g1");
        assert_eq!(tp.size(), 2);
    }

    #[test]
    fn add_replica_first_and_last_stage_touch_leader() {
        let mut t = Topology::pipeline("mw", &[1, 1], 26_000);
        let (_, fresh0) = t.add_replica(0, 27_000);
        assert!(fresh0.iter().any(|w| w.members[0] == NodeId::Leader));
        let (_, fresh1) = t.add_replica(1, 28_000);
        assert!(fresh1.iter().any(|w| w.members[1] == NodeId::Leader));
    }

    #[test]
    fn remove_node_drops_exactly_its_worlds() {
        let mut t = Topology::pipeline("mw", &[1, 2, 1], 29_000);
        let p3 = NodeId::worker(1, 1);
        let dead = t.remove_node(p3);
        // P3 touched two worlds (from P1, to P4) — Fig. 2b.
        assert_eq!(dead.len(), 2);
        assert_eq!(t.worlds.len(), 4);
        assert!(t.worlds_of(p3).is_empty());
        // P2's worlds intact.
        let p2 = NodeId::worker(1, 0);
        assert_eq!(t.worlds_of(p2).len(), 2);
    }

    #[test]
    fn remint_replica_after_nonhead_death_refreshes_tp_world_only() {
        let mut t = Topology::pipeline_tp("mw", &[1, 1], &[1, 2], 30_500);
        let shard1 = NodeId::Worker { stage: 1, replica: 0, shard: 1 };
        let edges_before: Vec<String> = t
            .worlds_of(NodeId::worker(1, 0))
            .iter()
            .filter(|w| !w.is_tp())
            .map(|w| w.name.clone())
            .collect();
        let (removed, fresh) = t.remint_replica(shard1, 31_500);
        assert_eq!(removed, vec!["mw-tp-s1r0".to_string()]);
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh[0].name, "mw-tp-s1r0#g1");
        assert_eq!(fresh[0].members, t.tp_world_of(shard1).unwrap().members);
        // The head's healthy edges are untouched.
        let edges_after: Vec<String> = t
            .worlds_of(NodeId::worker(1, 0))
            .iter()
            .filter(|w| !w.is_tp())
            .map(|w| w.name.clone())
            .collect();
        assert_eq!(edges_before, edges_after);
    }

    #[test]
    fn remint_replica_after_head_death_refreshes_edges_too() {
        let mut t = Topology::pipeline_tp("mw", &[1, 1], &[1, 2], 32_500);
        let head = NodeId::worker(1, 0);
        let (removed, fresh) = t.remint_replica(head, 33_500);
        // Broken: upstream edge + out edge + TP world.
        assert_eq!(removed.len(), 3);
        assert_eq!(fresh.len(), 3);
        assert!(fresh.iter().all(|w| w.name.contains("#g1")));
        assert_eq!(fresh.iter().filter(|w| w.is_tp()).count(), 1);
        // Same member sets, fresh names: the replica id survives.
        assert_eq!(t.tp_world_of(head).unwrap().name, "mw-tp-s1r0#g1");
        assert_eq!(t.in_edges(head).len(), 1);
        assert_eq!(t.out_edges(head).len(), 1);
    }

    #[test]
    fn shape_string() {
        assert_eq!(Topology::pipeline("x", &[1, 2, 1], 30_000).shape(), "1x2x1");
    }

    #[test]
    fn unplaced_topology_derives_no_hostmaps() {
        let t = Topology::pipeline("mw", &[1, 2, 1], 34_000);
        assert!(t.hosts.is_empty());
        for w in &t.worlds {
            assert_eq!(t.world_hostmap(w), None);
        }
    }

    #[test]
    fn place_replicas_keeps_shards_together_and_splits_edges() {
        let mut t = Topology::pipeline_tp("mw", &[1, 2], &[1, 2], 35_000);
        t.place_replicas(3);
        // Leader + s0r0 share host 0; s1r0 → host 1, s1r1 → host 2.
        assert_eq!(t.host_of(NodeId::Leader), 0);
        assert_eq!(t.host_of(NodeId::worker(0, 0)), 0);
        assert_eq!(t.host_of(NodeId::worker(1, 0)), 1);
        assert_eq!(t.host_of(NodeId::Worker { stage: 1, replica: 1, shard: 1 }), 2);
        // TP worlds stay intra-host → no spec needed.
        let tp = t.tp_world_of(NodeId::worker(1, 0)).unwrap();
        assert_eq!(t.world_hostmap(tp), None);
        // The in edge is co-located too (leader and s0r0 on host 0).
        let in_edge = t.in_edges(NodeId::worker(0, 0))[0];
        assert_eq!(t.world_hostmap(in_edge), None);
        // Cross-host pipeline edges get a rank-aligned comma list.
        let e = t.out_edges(NodeId::worker(0, 0));
        let specs: Vec<Option<String>> = e.iter().map(|w| t.world_hostmap(w)).collect();
        assert_eq!(specs, vec![Some("0,1".into()), Some("0,2".into())]);
        let out = t.in_edges(NodeId::Leader)[0];
        assert_eq!(t.world_hostmap(out), Some("1,0".into()));
    }

    #[test]
    fn host_placement_survives_json_and_node_removal() {
        let mut t = Topology::pipeline("mw", &[1, 2, 1], 36_000);
        t.place_replicas(2);
        let back = Topology::from_json(&t.to_json()).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.host_of(NodeId::worker(1, 0)), 1);
        assert_eq!(back.host_of(NodeId::worker(1, 1)), 0, "round-robin wraps");
        // Unplaced topologies serialize without a hosts key at all.
        let plain = Topology::pipeline("mw", &[1, 1], 37_000);
        assert!(!plain.to_json().to_string().contains("hosts"));
        // Removing a node forgets its placement.
        let p3 = NodeId::worker(1, 1);
        t.remove_node(p3);
        assert!(!t.hosts.contains_key(&p3));
        assert_eq!(t.host_of(p3), 0, "unplaced falls back to host 0");
    }
}
