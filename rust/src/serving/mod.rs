//! The model-serving framework built on MultiWorld.
//!
//! This is the "full-fledged model serving system" the paper's
//! conclusion names as future work, built here as a first-class part of
//! the reproduction: a leader process batches and routes requests into a
//! stage-partitioned pipeline whose workers execute AOT-compiled model
//! stages (see [`crate::runtime`]) and forward activations through
//! MultiWorld worlds — one small world per pipeline edge, exactly the
//! Fig. 2 rhombus.
//!
//! Pieces (each independently testable):
//!
//! * [`request`] — request/response types and the Poisson workload
//!   generator.
//! * [`batcher`] — the dynamic batcher (max batch / timeout fill).
//! * [`router`] — replica selection with least-inflight routing,
//!   backpressure and replica death handling.
//! * [`topology`] — names and members of every world in a pipeline
//!   deployment (leader↔stage0, stageᵢ↔stageᵢ₊₁ bipartite, last↔leader).
//! * [`stage_worker`] — the worker loop: receive activation from any
//!   in-edge, run the stage, route downstream.
//! * [`leader`] — the leader loop: batch, inject, collect, measure.
//! * [`controller`] — elasticity: watches load and failures, decides
//!   scale-out/in and recovery, and drives online instantiation.

pub mod batcher;
pub mod controller;
pub mod leader;
pub mod request;
pub mod router;
pub mod stage_worker;
pub mod topology;

pub use batcher::DynamicBatcher;
pub use controller::{Controller, ScalingPolicy};
pub use leader::{Leader, LeaderReport};
pub use request::{Request, RequestGen, Response};
pub use router::ReplicaRouter;
pub use stage_worker::{run_stage_worker, StageWorkerConfig, WorkerStats};
pub use topology::{NodeId, Topology, WorldDef};
