//! The model-serving framework built on MultiWorld.
//!
//! This is the "full-fledged model serving system" the paper's
//! conclusion names as future work, built here as a first-class part of
//! the reproduction — an **always-on serving runtime**: clients submit
//! requests through an open ingress and get back handles; a
//! deadline-aware admission queue feeds a stage-partitioned pipeline
//! whose workers execute AOT-compiled model stages (see
//! [`crate::runtime`]) and forward activations through MultiWorld
//! worlds — one small world per pipeline edge, exactly the Fig. 2
//! rhombus — while a closed-loop autoscaler grows and shrinks the
//! topology under live traffic.
//!
//! **Request lifecycle.** [`Leader::submit`] runs admission control
//! (sequence-length validation, bounded queue depth with load-shedding,
//! per-request SLO deadline stamping) and returns a [`RequestHandle`]
//! that resolves to exactly one outcome: a [`Response`], an SLO-deadline
//! drop, or an admission rejection. One-shot requests
//! (`max_tokens = 1`, the default) take the legacy run-to-completion
//! path: a *dispatcher* thread batches admitted requests (expired ones
//! are dropped at the queue head, before any forward pass) and routes
//! each batch to a live replica; a *collector* thread harvests
//! responses, resolves handles, and re-dispatches batches lost to dead
//! workers (at-least-once with response dedupe). The run-to-completion
//! [`Leader::serve`] survives as a compatibility wrapper over the same
//! machinery: submit-all (with backpressure instead of shedding),
//! wait-all, report.
//!
//! **Continuous batching.** Multi-token requests (`max_tokens > 1`,
//! or `MW_MAX_TOKENS` as the deployment default) route through the
//! streaming decode loop instead: a persistent per-deployment scheduler
//! keeps one *lane* per stage-0 replica edge with a slot-addressed
//! **running batch**, and re-schedules it **every decode iteration** —
//! queued requests admit into free slots (prefill) and finished or
//! SLO-expired ones retire, mid-flight, without waiting for the rest of
//! the batch. Each iteration travels as a [`decode::StepFrame`] inside
//! the ordinary [`stage_worker::Envelope`]: per-slot directives
//! (prefill/decode/retire, applied idempotently to the workers'
//! [`crate::runtime::decode::DecodeSlots`]) plus the slot-packed token
//! payload. The collector harvests one token per occupied slot per
//! frame and pushes it down the request's [`RequestHandle`] token
//! stream ([`StreamEvent::Token`], terminated by [`StreamEvent::Done`])
//! — so the handle is a *token stream*, and the SLO splits into
//! time-to-first-token (`MW_SLO_TTFT_MS`) and inter-token gap
//! (`MW_SLO_ITL_MS`) instead of a single whole-request deadline. The
//! leader is the source of truth for decode state: generated tokens
//! live leader-side, worker slot state is soft, and a request whose
//! lane dies mid-decode **re-prefills** (prompt + everything generated
//! so far) on the next live lane — a killed worker costs recomputation,
//! never a lost request. `MW_DECODE_GANG=1` keeps iteration framing but
//! admits only into an empty batch (gang scheduling), the ablation
//! baseline the continuous-batching benchmark leg is measured against.
//!
//! **Serving parallelism.** Two axes compose:
//!
//! * *Pipeline/replica parallelism*: stages are partitioned across
//!   workers; each stage may be replicated, with one two-member edge
//!   world per (upstream replica, downstream replica) pair and
//!   least-inflight routing between them.
//! * *Tensor parallelism*: each replica of a stage may be split into
//!   `tp` **shards** — workers named `s{stage}r{replica}t{shard}`
//!   (shard 0, the *head*, omits the suffix and is the only shard on
//!   edge worlds) — joined by one multi-member `tp-s{stage}r{replica}`
//!   world per replica. Per batch, the head `broadcast`s the activation
//!   across the TP world, every shard computes its weight slice, and
//!   the partial outputs combine with `all_reduce(Sum)` before the head
//!   forwards downstream. A `tp = 1` deployment is byte-identical
//!   (world names and members) to the pre-sharding scheme.
//!
//! **Multi-tenancy.** Every [`Request`] carries a [`TenantId`]
//! (builder: [`Request::with_tenant`]; untagged requests belong to the
//! `"default"` tenant). A deployment opts into per-tenant SLO classes
//! through `MW_TENANTS` — grammar
//! `name[:key=val,...][;name[:key=val,...]]...` with keys `weight`
//! (admission share, default 1), `slo_ms` / `slo_ttft_ms` / `slo_itl_ms`
//! (per-tenant latency targets; 0 or absent inherits the global
//! `MW_SLO_MS` / `MW_SLO_TTFT_MS` / `MW_SLO_ITL_MS`), and `depth`
//! (per-tenant admission bound; 0 or absent inherits
//! `MW_ADMISSION_DEPTH`). Example:
//! `MW_TENANTS='gold:weight=4,slo_ms=50;free:weight=1,slo_ms=500'`.
//! With a tenant table configured:
//!
//! * the admission queue becomes a **weighted-fair** queue — one
//!   sub-queue per tenant, drained deficit-round-robin by weight, so a
//!   4:1 gold:free weight split admits in a 4:1 ratio under backlog
//!   while either class alone uses the full capacity (work-conserving);
//!   the decode tick admits into free slots through the same DRR drain,
//!   so continuous-batching slot admission respects the same shares;
//! * admission bounds are **per-tenant**: a bursting tenant sheds or
//!   backpressures *its own* traffic at its own `depth` while other
//!   tenants' sub-queues stay open (`serving.rejected.queue_full.
//!   tenant.<name>` counts the sheds);
//! * SLO stamping, deadline drops, TTFT windows and completion counts
//!   are tracked per tenant (`serving.{completed,dropped.deadline}.
//!   tenant.<name>` counters, `serving.ttft_ms.tenant.<name>` windows,
//!   `serving.queue.depth.tenant.<name>` gauges);
//! * the autoscaler samples per-tenant depth and recent p99
//!   ([`autoscaler::TenantSignal`]) and attributes an SLO breach to the
//!   tenant furthest over its own target (`serving.autoscale.
//!   tenant_breach.<name>`, plus a `tenant` field on the
//!   `autoscale.out` log event) — a gold tenant drowning behind
//!   free-tier traffic is visible even when the aggregate p99 looks
//!   healthy.
//!
//! Requests naming a tenant absent from the table fold into the
//! implicit `default` class. With `MW_TENANTS` unset (the default)
//! there is exactly one tenant: the queue is plain FIFO, no per-tenant
//! metric names are created, and the wire format, metric surface and
//! scheduling behavior are byte-identical to the pre-tenancy runtime.
//!
//! **Elasticity, closed loop.** The [`Autoscaler`] samples live signals
//! every tick — admission-queue depth per alive replica, recent p99
//! latency vs. the SLO target, replica liveness — and drives
//! [`Controller::maybe_scale_out`] / [`Controller::scale_in`] with
//! hysteresis and cooldown. Scale-in is graceful: the victim's
//! leader-facing edges are quiesced, outstanding batches drain, then
//! the replica is retired. Decisions are observable through the
//! controller's `Action` log, the `serving.autoscale.{out,in}`
//! counters, and `autoscale.*` log events.
//!
//! **Fast recovery.** Recovery latency is a first-class metric, not
//! just recovery correctness: set `MW_SPARES=N` and the launcher keeps
//! `N` pre-warmed spare workers on standby — spawned at cluster start,
//! registered, heartbeating, with every stage's weights pre-loaded from
//! the per-host [`spares::WeightCache`] — and a `WorldEvent::Broken`
//! verdict *promotes* a spare into the dead worker's identity instead
//! of cold-spawning, then asynchronously backfills the pool. The
//! autoscaler treats pool headroom as license to scale out ahead of its
//! cooldown (promote-then-backfill is near-free). `MW_WEIGHT_CACHE=0`
//! disables the host cache (every spawn pays the full weight load
//! again); `MW_SPARES=0` (the default) keeps the original
//! respawn-from-scratch recovery byte for byte. The recovery-path
//! latency distribution rides the `serving.mttr_ms` sliding window and
//! the pool is observable via `serving.spares.{pool,promoted,
//! backfilled}`.
//!
//! Fault domains are shard-granular and compose with scaling: a dead
//! shard breaks its replica's TP world (plus the head's edge worlds
//! when the head died) and the controller re-mints exactly those worlds
//! under fresh generation-tagged names, respawning only the dead shard;
//! TP neighbors rejoin over their control channels and are never
//! declared dead on TP-world evidence alone (see [`controller`]). A
//! replica can be killed, recovered, and a fresh replica scaled out in
//! the same run.
//!
//! The fault model goes beyond clean kills: the transport layer can
//! deterministically inject *gray* failures — stalled links, dropped
//! or truncated frames, one-way partitions, bandwidth caps — via
//! [`crate::mwccl::transport::fault`] (`WorldOptions::with_fault_plan`,
//! env `MW_FAULT_PLAN`/`MW_FAULT_SEED`, runtime handle
//! `InProcCluster::faults()`). Injections are observable as
//! `fault.injected.<kind>` counters plus `fault.injected` log events,
//! and detected wire corruption rides `transport.corrupt_frames` — the
//! signals `tests/serving_gray_failure.rs` asserts on. Deliberate world
//! breaks announce themselves on the wire (`Link::farewell`), so
//! failure attribution never convicts a live rank that aborted a wedged
//! collective.
//!
//! Pieces (each independently testable):
//!
//! * [`request`] — request/response types, the per-request
//!   [`RequestHandle`]/outcome machinery, and the Poisson workload
//!   generator.
//! * [`batcher`] — the deadline-aware admission queue + dynamic batcher
//!   (bounded depth, load-shedding, SLO expiry before dispatch,
//!   max-batch/timeout fill).
//! * [`decode`] — the step-frame wire protocol and the iteration-level
//!   scheduler state behind the continuous-batching decode loop.
//! * [`router`] — replica selection with least-inflight routing,
//!   backpressure and replica death handling.
//! * [`topology`] — names and members of every world in a pipeline
//!   deployment (leader↔stage0, stageᵢ↔stageᵢ₊₁ bipartite, last↔leader,
//!   plus one intra-replica TP world per sharded replica).
//! * [`stage_worker`] — the worker loop: receive activation from any
//!   in-edge, run the TP inner loop (or the stage directly), route
//!   downstream; non-head shards run the TP follower loop.
//! * [`leader`] — the always-on runtime: ingress/admission, the
//!   dispatcher and collector threads, retry, SLO accounting.
//! * [`controller`] — elasticity mechanisms: online instantiation for
//!   scale-out, drain-and-retire for scale-in, shard-granularity
//!   recovery for failures.
//! * [`autoscaler`] — the elasticity *policy* loop: samples load
//!   signals and drives the controller under live traffic.
//! * [`spares`] — the host-side weight cache behind the pre-warmed
//!   spare pool (`MW_SPARES` / `MW_WEIGHT_CACHE`, see "Fast recovery"
//!   above).

pub mod autoscaler;
pub mod batcher;
pub mod controller;
pub mod decode;
pub mod leader;
pub mod request;
pub mod router;
pub mod spares;
pub mod stage_worker;
pub mod topology;

pub use autoscaler::{AutoscalePolicy, Autoscaler, AutoscalerHandle, LoadSignals, TenantSignal};
pub use batcher::{DynamicBatcher, TenantClass};
pub use controller::{Controller, ScalingPolicy};
pub use decode::{StepEntry, StepFrame, StepPhase};
pub use leader::{Leader, LeaderReport};
pub use request::{
    DropReason, Outcome, RejectReason, Request, RequestGen, RequestHandle, Response,
    StreamEvent, TenantId, DEFAULT_TENANT,
};
pub use router::{DispatchToken, ReplicaRouter};
pub use spares::{host_cache, WeightCache};
pub use stage_worker::{run_stage_worker, StageWorkerConfig, WorkerStats};
pub use topology::{NodeId, Topology, WorldDef, WorldKind};
