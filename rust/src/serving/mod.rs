//! The model-serving framework built on MultiWorld.
//!
//! This is the "full-fledged model serving system" the paper's
//! conclusion names as future work, built here as a first-class part of
//! the reproduction: a leader process batches and routes requests into
//! a stage-partitioned pipeline whose workers execute AOT-compiled
//! model stages (see [`crate::runtime`]) and forward activations
//! through MultiWorld worlds — one small world per pipeline edge,
//! exactly the Fig. 2 rhombus.
//!
//! **Serving parallelism.** Two axes compose:
//!
//! * *Pipeline/replica parallelism*: stages are partitioned across
//!   workers; each stage may be replicated, with one two-member edge
//!   world per (upstream replica, downstream replica) pair and
//!   least-inflight routing between them.
//! * *Tensor parallelism*: each replica of a stage may be split into
//!   `tp` **shards** — workers named `s{stage}r{replica}t{shard}`
//!   (shard 0, the *head*, omits the suffix and is the only shard on
//!   edge worlds) — joined by one multi-member `tp-s{stage}r{replica}`
//!   world per replica. Per batch, the head `broadcast`s the activation
//!   across the TP world, every shard computes its weight slice, and
//!   the partial outputs combine with `all_reduce(Sum)` before the head
//!   forwards downstream — the first worlds in the system with more
//!   than two members, driving the flat/ring collective selector in
//!   the serving hot path. A `tp = 1` deployment is byte-identical
//!   (world names and members) to the pre-sharding scheme.
//!
//! Fault domains are shard-granular: a dead shard breaks its replica's
//! TP world (plus the head's edge worlds when the head died) and the
//! controller re-mints exactly those worlds under fresh
//! generation-tagged names, respawning only the dead shard; TP
//! neighbors rejoin over their control channels and are never declared
//! dead on TP-world evidence alone (see [`controller`]).
//!
//! Pieces (each independently testable):
//!
//! * [`request`] — request/response types and the Poisson workload
//!   generator.
//! * [`batcher`] — the dynamic batcher (max batch / timeout fill).
//! * [`router`] — replica selection with least-inflight routing,
//!   backpressure and replica death handling.
//! * [`topology`] — names and members of every world in a pipeline
//!   deployment (leader↔stage0, stageᵢ↔stageᵢ₊₁ bipartite, last↔leader,
//!   plus one intra-replica TP world per sharded replica).
//! * [`stage_worker`] — the worker loop: receive activation from any
//!   in-edge, run the TP inner loop (or the stage directly), route
//!   downstream; non-head shards run the TP follower loop.
//! * [`leader`] — the leader loop: batch, inject, collect, measure.
//! * [`controller`] — elasticity: watches load and failures, decides
//!   scale-out/in and shard-granularity recovery, and drives online
//!   instantiation.

pub mod batcher;
pub mod controller;
pub mod leader;
pub mod request;
pub mod router;
pub mod stage_worker;
pub mod topology;

pub use batcher::DynamicBatcher;
pub use controller::{Controller, ScalingPolicy};
pub use leader::{Leader, LeaderReport};
pub use request::{Request, RequestGen, Response};
pub use router::ReplicaRouter;
pub use stage_worker::{run_stage_worker, StageWorkerConfig, WorkerStats};
pub use topology::{NodeId, Topology, WorldDef, WorldKind};
