//! Replica routing: pick which replica of a (replicated) stage gets the
//! next batch. Least-inflight with round-robin tie-break, inflight caps
//! for backpressure, and replica death/addition at runtime — the
//! data-plane half of the paper's stage-level scaling story.
//!
//! Dispatches are **epoch-stamped**: [`ReplicaRouter::pick`] returns a
//! [`DispatchToken`] carrying the replica's liveness epoch, and
//! [`ReplicaRouter::complete`] ignores tokens from a dead epoch. Without
//! the stamp, a completion racing `mark_dead` + revival would decrement
//! the *new* epoch's inflight (a phantom completion from work the dead
//! replica never finished), skewing least-inflight routing and letting
//! the revived replica overshoot its inflight cap.

use std::collections::BTreeMap;
use std::sync::Mutex;

#[derive(Clone, Debug, Default)]
struct ReplicaState {
    inflight: usize,
    dispatched: u64,
    alive: bool,
    /// Liveness epoch, bumped on every `mark_dead`. Completions carry
    /// the epoch they were dispatched under; stale ones are ignored.
    epoch: u64,
}

/// Proof of one dispatch: which replica took the batch and under which
/// liveness epoch. Hand it back via [`ReplicaRouter::complete`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DispatchToken {
    pub replica: String,
    pub epoch: u64,
}

/// See module docs. Keyed by an opaque replica id (the edge-world name
/// in the pipeline).
#[derive(Default)]
pub struct ReplicaRouter {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    replicas: BTreeMap<String, ReplicaState>,
    rr_cursor: usize,
    max_inflight: usize,
}

impl ReplicaRouter {
    /// `max_inflight` of 0 means unbounded.
    pub fn new(max_inflight: usize) -> Self {
        ReplicaRouter {
            inner: Mutex::new(Inner {
                replicas: BTreeMap::new(),
                rr_cursor: 0,
                max_inflight,
            }),
        }
    }

    pub fn add_replica(&self, id: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner
            .replicas
            .entry(id.to_string())
            .or_insert_with(ReplicaState::default)
            .alive = true;
    }

    /// A replica died (its edge world broke): stop routing to it. Its
    /// inflight work is presumed lost; callers requeue. The epoch bump
    /// invalidates every outstanding [`DispatchToken`] so a straggling
    /// completion from the dead epoch can't touch a revival's counters.
    pub fn mark_dead(&self, id: &str) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(r) = inner.replicas.get_mut(id) {
            r.alive = false;
            r.inflight = 0;
            r.epoch += 1;
        }
    }

    pub fn remove_replica(&self, id: &str) {
        self.inner.lock().unwrap().replicas.remove(id);
    }

    /// Choose the next replica: among alive replicas under the inflight
    /// cap, least inflight wins; ties break round-robin. `None` when
    /// everything is dead or saturated (backpressure).
    pub fn pick(&self) -> Option<DispatchToken> {
        let mut inner = self.inner.lock().unwrap();
        let cap = inner.max_inflight;
        let candidates: Vec<(String, usize)> = inner
            .replicas
            .iter()
            .filter(|(_, s)| s.alive && (cap == 0 || s.inflight < cap))
            .map(|(k, s)| (k.clone(), s.inflight))
            .collect();
        if candidates.is_empty() {
            return None;
        }
        let min_inflight = candidates.iter().map(|(_, i)| *i).min().unwrap();
        let tied: Vec<&String> = candidates
            .iter()
            .filter(|(_, i)| *i == min_inflight)
            .map(|(k, _)| k)
            .collect();
        let cursor = inner.rr_cursor;
        inner.rr_cursor = inner.rr_cursor.wrapping_add(1);
        let chosen = tied[cursor % tied.len()].clone();
        let st = inner.replicas.get_mut(&chosen).unwrap();
        st.inflight += 1;
        st.dispatched += 1;
        Some(DispatchToken { epoch: st.epoch, replica: chosen })
    }

    /// A dispatched batch completed (or failed) on the token's replica.
    /// A token minted before the replica's last `mark_dead` is stale —
    /// its inflight was already forgotten with the dead epoch — and is
    /// ignored rather than debited against the revived replica.
    pub fn complete(&self, token: &DispatchToken) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(r) = inner.replicas.get_mut(&token.replica) {
            if r.epoch == token.epoch {
                r.inflight = r.inflight.saturating_sub(1);
            }
        }
    }

    /// (alive, total) replica counts.
    pub fn counts(&self) -> (usize, usize) {
        let inner = self.inner.lock().unwrap();
        let alive = inner.replicas.values().filter(|r| r.alive).count();
        (alive, inner.replicas.len())
    }

    /// Dispatch totals per replica (diagnostics / load-balance tests).
    pub fn dispatch_counts(&self) -> BTreeMap<String, u64> {
        self.inner
            .lock()
            .unwrap()
            .replicas
            .iter()
            .map(|(k, s)| (k.clone(), s.dispatched))
            .collect()
    }

    /// Total inflight across alive replicas.
    pub fn inflight(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .replicas
            .values()
            .filter(|r| r.alive)
            .map(|r| r.inflight)
            .sum()
    }

    pub fn alive_replicas(&self) -> Vec<String> {
        self.inner
            .lock()
            .unwrap()
            .replicas
            .iter()
            .filter(|(_, s)| s.alive)
            .map(|(k, _)| k.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_when_balanced() {
        let r = ReplicaRouter::new(0);
        r.add_replica("a");
        r.add_replica("b");
        let first = r.pick().unwrap();
        r.complete(&first);
        let second = r.pick().unwrap();
        r.complete(&second);
        assert_ne!(first.replica, second.replica, "tie-break must rotate");
    }

    #[test]
    fn least_inflight_wins() {
        let r = ReplicaRouter::new(0);
        r.add_replica("a");
        r.add_replica("b");
        let x = r.pick().unwrap(); // x has 1 inflight now
        let y = r.pick().unwrap();
        assert_ne!(x.replica, y.replica);
        r.complete(&y); // y back to 0, x still 1
        assert_eq!(r.pick().unwrap().replica, y.replica);
    }

    #[test]
    fn inflight_cap_backpressures() {
        let r = ReplicaRouter::new(2);
        r.add_replica("a");
        let t1 = r.pick().unwrap();
        assert!(r.pick().is_some());
        assert!(r.pick().is_none(), "cap reached");
        r.complete(&t1);
        assert!(r.pick().is_some());
    }

    #[test]
    fn dead_replica_not_picked() {
        let r = ReplicaRouter::new(0);
        r.add_replica("a");
        r.add_replica("b");
        r.mark_dead("a");
        for _ in 0..10 {
            assert_eq!(r.pick().unwrap().replica, "b");
        }
        assert_eq!(r.counts(), (1, 2));
    }

    #[test]
    fn all_dead_is_none() {
        let r = ReplicaRouter::new(0);
        r.add_replica("a");
        r.mark_dead("a");
        assert!(r.pick().is_none());
    }

    #[test]
    fn revival_via_add_replica() {
        // Online instantiation: a replacement replica under the same or a
        // new id starts taking traffic.
        let r = ReplicaRouter::new(0);
        r.add_replica("a");
        r.mark_dead("a");
        assert!(r.pick().is_none());
        r.add_replica("a2");
        assert_eq!(r.pick().unwrap().replica, "a2");
    }

    #[test]
    fn load_spreads_evenly() {
        let r = ReplicaRouter::new(0);
        for id in ["a", "b", "c"] {
            r.add_replica(id);
        }
        for _ in 0..300 {
            let t = r.pick().unwrap();
            r.complete(&t);
        }
        let counts = r.dispatch_counts();
        for (_, c) in counts {
            assert_eq!(c, 100);
        }
    }

    #[test]
    fn mark_dead_resets_inflight() {
        let r = ReplicaRouter::new(1);
        r.add_replica("a");
        let _ = r.pick().unwrap();
        r.mark_dead("a");
        r.add_replica("a"); // revived (new worker, same edge id)
        assert!(r.pick().is_some(), "inflight from the dead epoch is forgotten");
    }

    #[test]
    fn stale_complete_across_revival_is_ignored() {
        // Regression: a completion that raced mark_dead + revival used
        // to decrement the NEW epoch's inflight — a phantom completion
        // for work the dead replica never finished. With max_inflight=1
        // that would free a slot the revived replica still occupies.
        let r = ReplicaRouter::new(1);
        r.add_replica("a");
        let stale = r.pick().unwrap(); // dispatched under epoch 0
        r.mark_dead("a"); // batch presumed lost; epoch bumps to 1
        r.add_replica("a"); // revived
        let live = r.pick().unwrap(); // fills the revived cap (epoch 1)
        assert_ne!(stale.epoch, live.epoch);
        // The dead epoch's straggler finally reports in: must be a no-op.
        r.complete(&stale);
        assert_eq!(r.inflight(), 1, "stale complete must not free a slot");
        assert!(r.pick().is_none(), "cap still honored after stale complete");
        // The live epoch's completion works as usual.
        r.complete(&live);
        assert_eq!(r.inflight(), 0);
        assert!(r.pick().is_some());
    }
}
