//! The stage worker: the compute node of the serving pipeline.
//!
//! A worker owns one model stage (an AOT PJRT executable), is the
//! downstream member of a world per upstream neighbor and the upstream
//! member of a world per downstream neighbor, and loops:
//!
//! ```text
//!   wait_any(pending irecv over in-edges)        ← non-blocking CCL +
//!      → unpack envelope → stage.run             busy-wait poller
//!      → pick out-edge (least-inflight router)   ← stage-level routing
//!      → send envelope downstream
//! ```
//!
//! Fault tolerance: a broken in-edge is dropped (the worker keeps
//! serving its other edges — Fig. 2b); a broken out-edge is marked dead
//! in the router and the batch is re-routed to a surviving replica.
//! Online instantiation: the control channel delivers fresh
//! [`WorldDef`]s; the worker joins them with `initialize_world_async`,
//! so existing traffic never stalls (Fig. 5).

use super::topology::{NodeId, Topology, WorldDef};
use crate::multiworld::{MwError, WorldEvent, WorldManager};
use crate::mwccl::{CclError, Work, WorldOptions};
use crate::runtime::StageRunner;
use crate::serving::router::ReplicaRouter;
use crate::tensor::{read_tensor, DType, Tensor};
use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

/// Data-plane tag (one logical stream per edge world; messages queue
/// FIFO under the tag).
pub const TAG_DATA: u64 = 1;

/// An in-flight unit: request-batch id + activation tensor, packed into
/// a U8 tensor so it rides the existing collectives.
#[derive(Clone, Debug, PartialEq)]
pub struct Envelope {
    pub id: u64,
    pub tensor: Tensor,
}

impl Envelope {
    pub fn pack(&self) -> Tensor {
        let mut bytes = Vec::with_capacity(8 + 64 + self.tensor.byte_len());
        bytes.extend_from_slice(&self.id.to_le_bytes());
        crate::tensor::write_tensor(&mut bytes, &self.tensor).expect("pack envelope");
        let n = bytes.len();
        Tensor::from_bytes(DType::U8, &[n], bytes).expect("pack envelope tensor")
    }

    pub fn unpack(t: &Tensor) -> anyhow::Result<Envelope> {
        anyhow::ensure!(t.dtype() == DType::U8, "envelope must be U8");
        let bytes = t.bytes();
        anyhow::ensure!(bytes.len() >= 8, "envelope too short");
        let id = u64::from_le_bytes(bytes[0..8].try_into().unwrap());
        let tensor = read_tensor(&mut &bytes[8..])?;
        Ok(Envelope { id, tensor })
    }
}

/// Control-plane messages to a running worker.
#[derive(Debug)]
pub enum TopoUpdate {
    /// Join a fresh world (online instantiation / scale-out).
    AddWorld(WorldDef),
    /// Drain and exit.
    Shutdown,
}

/// Configuration for one worker node.
pub struct StageWorkerConfig {
    pub node: NodeId,
    pub topology: Topology,
    /// Stage executable; `None` = forward-only (transport benches).
    pub stage: Option<Arc<StageRunner>>,
    pub opts: WorldOptions,
    /// Control channel (None = static topology).
    pub control: Option<Receiver<TopoUpdate>>,
    pub stop: Arc<AtomicBool>,
}

/// What a worker did during its run.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub processed: u64,
    pub forwarded: u64,
    pub in_edge_failures: u64,
    pub out_edge_failures: u64,
    pub joined_worlds: u64,
}

/// Initialize this node's side of every world it belongs to, in
/// parallel (each `World::init` blocks until the peer arrives).
pub fn init_node_worlds(
    mgr: &WorldManager,
    topo: &Topology,
    node: NodeId,
    opts: &WorldOptions,
) -> anyhow::Result<()> {
    let defs: Vec<WorldDef> = topo.worlds_of(node).into_iter().cloned().collect();
    let handles: Vec<_> = defs
        .into_iter()
        .map(|def| {
            let rank = def.rank_of(node).expect("member");
            let addr: SocketAddr = format!("127.0.0.1:{}", def.store_port).parse().unwrap();
            mgr_init_async(mgr.clone(), def.name.clone(), rank, 2, addr, opts.clone())
        })
        .collect();
    for h in handles {
        h.join()
            .map_err(|_| anyhow::anyhow!("init thread panicked"))??;
    }
    Ok(())
}

fn mgr_init_async(
    mgr: WorldManager,
    name: String,
    rank: usize,
    size: usize,
    addr: SocketAddr,
    opts: WorldOptions,
) -> std::thread::JoinHandle<Result<(), MwError>> {
    std::thread::Builder::new()
        .name(format!("init-{name}-r{rank}"))
        .spawn(move || mgr.initialize_world(&name, rank, size, addr, opts))
        .expect("spawn world init")
}

/// Run the worker loop until `stop` or until every in-edge is gone and
/// no control channel can bring more.
pub fn run_stage_worker(mgr: WorldManager, cfg: StageWorkerConfig) -> anyhow::Result<WorkerStats> {
    let comm = mgr.communicator();
    let events = mgr.subscribe();
    let mut stats = WorkerStats::default();

    // Live edge sets.
    let mut in_edges: Vec<String> = cfg
        .topology
        .in_edges(cfg.node)
        .iter()
        .map(|w| w.name.clone())
        .collect();
    let out_router = ReplicaRouter::new(0);
    for w in cfg.topology.out_edges(cfg.node) {
        out_router.add_replica(&w.name);
    }

    // One posted irecv per live in-edge.
    let mut pending: HashMap<String, Work> = HashMap::new();
    for e in &in_edges {
        if let Ok(w) = comm.recv(e, 0, TAG_DATA) {
            pending.insert(e.clone(), w);
        }
    }

    let debug = std::env::var("MW_DEBUG").is_ok();
    let mut last_dbg = std::time::Instant::now();
    loop {
        if debug && last_dbg.elapsed() > Duration::from_secs(1) {
            last_dbg = std::time::Instant::now();
            eprintln!(
                "[worker {}] alive: in={:?} pending={} out={:?}",
                cfg.node,
                in_edges,
                pending.len(),
                out_router.alive_replicas()
            );
        }
        if cfg.stop.load(Ordering::Relaxed) {
            break;
        }
        // Control-plane: join new worlds without stalling the data path.
        if let Some(ctrl) = &cfg.control {
            while let Ok(update) = ctrl.try_recv() {
                match update {
                    TopoUpdate::AddWorld(def) => {
                        let rank = match def.rank_of(cfg.node) {
                            Some(r) => r,
                            None => continue, // not our world
                        };
                        let addr: SocketAddr =
                            format!("127.0.0.1:{}", def.store_port).parse().unwrap();
                        // Blocking init is fine *here*: the joiner is new
                        // and has no traffic yet. Existing members join
                        // via their own control threads concurrently.
                        mgr.initialize_world(&def.name, rank, 2, addr, cfg.opts.clone())?;
                        stats.joined_worlds += 1;
                        if rank == 1 {
                            in_edges.push(def.name.clone());
                            if let Ok(w) = comm.recv(&def.name, 0, TAG_DATA) {
                                pending.insert(def.name.clone(), w);
                            }
                        } else {
                            out_router.add_replica(&def.name);
                        }
                    }
                    TopoUpdate::Shutdown => {
                        cfg.stop.store(true, Ordering::Relaxed);
                    }
                }
            }
        }
        // Fault events: drop broken edges.
        while let Ok(evt) = events.try_recv() {
            if let WorldEvent::Broken { world, .. } = evt {
                if in_edges.contains(&world) {
                    in_edges.retain(|e| e != &world);
                    pending.remove(&world);
                    stats.in_edge_failures += 1;
                } else {
                    out_router.mark_dead(&world);
                    stats.out_edge_failures += 1;
                }
            }
        }
        if pending.is_empty() {
            if cfg.control.is_none() && in_edges.is_empty() {
                break; // nothing will ever arrive again
            }
            std::thread::sleep(Duration::from_millis(2));
            continue;
        }

        // Poll for a completed receive (bounded so control/stop stay live).
        let names: Vec<String> = pending.keys().cloned().collect();
        let works: Vec<Work> = names.iter().map(|n| pending[n].clone()).collect();
        let Some(idx) = comm.wait_any_deadline(&works, Some(Duration::from_millis(20))) else {
            continue;
        };
        let edge = names[idx].clone();
        let work = pending.remove(&edge).unwrap();
        match work.wait() {
            Ok(Some(packed)) => {
                // Re-post the receive on this edge first (keep the pipe full).
                if let Ok(w) = comm.recv(&edge, 0, TAG_DATA) {
                    pending.insert(edge.clone(), w);
                }
                let env = Envelope::unpack(&packed)?;
                let result = match &cfg.stage {
                    Some(stage) => stage.run(&env.tensor)?,
                    None => env.tensor, // forward-only mode
                };
                stats.processed += 1;
                // Route downstream, retrying across replicas on failure.
                let out = Envelope { id: env.id, tensor: result }.pack();
                loop {
                    let Some(target) = out_router.pick() else {
                        // No downstream alive: drop (leader will retry the batch).
                        break;
                    };
                    match comm.send_blocking(&target, out.clone(), 1, TAG_DATA) {
                        Ok(()) => {
                            out_router.complete(&target);
                            stats.forwarded += 1;
                            break;
                        }
                        Err(_) => {
                            out_router.mark_dead(&target);
                            stats.out_edge_failures += 1;
                        }
                    }
                }
            }
            Ok(None) => {}
            Err(e) => {
                if debug {
                    eprintln!("[worker {}] recv on {edge} failed: {e}", cfg.node);
                }
                // In-edge broke (remote error or watchdog abort).
                if matches!(
                    e,
                    CclError::RemoteError { .. }
                        | CclError::Aborted(_)
                        | CclError::WorldBroken(_)
                ) {
                    mgr.break_world(&edge, &e.to_string());
                    in_edges.retain(|x| x != &edge);
                    stats.in_edge_failures += 1;
                }
            }
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn envelope_roundtrip() {
        let mut rng = Rng::new(1);
        let t = Tensor::rand_f32(&[4, 8], &mut rng);
        let env = Envelope { id: 0xDEAD_BEEF, tensor: t.clone() };
        let packed = env.pack();
        assert_eq!(packed.dtype(), DType::U8);
        let back = Envelope::unpack(&packed).unwrap();
        assert_eq!(back.id, 0xDEAD_BEEF);
        assert_eq!(back.tensor.checksum(), t.checksum());
    }

    #[test]
    fn envelope_rejects_garbage() {
        let t = Tensor::from_bytes(DType::U8, &[4], vec![1, 2, 3, 4]).unwrap();
        assert!(Envelope::unpack(&t).is_err());
        let f = Tensor::zeros(DType::F32, &[4]);
        assert!(Envelope::unpack(&f).is_err());
    }

    #[test]
    fn envelope_empty_tensor() {
        let env = Envelope { id: 7, tensor: Tensor::zeros(DType::F32, &[0]) };
        let back = Envelope::unpack(&env.pack()).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.tensor.elems(), 0);
    }
}
