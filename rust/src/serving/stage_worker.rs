//! The stage worker: the compute node of the serving pipeline.
//!
//! A worker owns one *shard* of one model stage. The replica's **head**
//! (shard 0) is the downstream member of a world per upstream neighbor
//! and the upstream member of a world per downstream neighbor, and
//! loops:
//!
//! ```text
//!   wait_any(pending irecv over in-edges)        ← non-blocking CCL +
//!      → unpack envelope                           busy-wait poller
//!      → [tp > 1] broadcast activation over the
//!        intra-replica TP world, run own weight
//!        slice, all_reduce(Sum) partial outputs  ← the TP inner loop
//!      → [tp = 1] stage.run                      ← unsharded fast path
//!      → pick out-edge (least-inflight router)   ← stage-level routing
//!      → send envelope downstream
//! ```
//!
//! Continuous-batching step frames (see [`super::decode`]) ride the
//! same loop: when the envelope payload carries the step-frame magic,
//! the head first applies the frame's slot directives to its
//! [`crate::runtime::decode::DecodeSlots`] (idempotently — retries
//! resend identical frames), runs **one decode iteration** on the
//! slot-packed payload — through the very same TP round when sharded,
//! so the collective selector runs once per decode step — and forwards
//! the frame with the payload substituted. A corrupt frame increments
//! `serving.worker.step_corrupt` and is skipped, never fatal.
//!
//! Non-head shards sit on no edge worlds at all: they loop on the TP
//! world only — `broadcast` (receive the activation from the head),
//! compute their weight slice, `all_reduce` — so the first multi-member
//! worlds in the system drive the ring/flat collective selector in the
//! serving hot path, not just in benches.
//!
//! Fault tolerance: a broken in-edge is dropped (the worker keeps
//! serving its other edges — Fig. 2b); a broken out-edge is marked dead
//! in the router and the batch is re-routed to a surviving replica; a
//! broken TP world drops the replica out of the compute path (in-flight
//! batches are abandoned for the leader to retry) until the controller
//! re-mints a fresh TP world and the surviving shards rejoin it over
//! their control channels. Online instantiation: the control channel
//! delivers fresh [`WorldDef`]s; the worker joins them with blocking
//! init on the control path, so existing traffic never stalls (Fig. 5).

use super::topology::{NodeId, Topology, WorldDef};
use crate::config::CollOp;
use crate::multiworld::{MwError, WorldCommunicator, WorldEvent, WorldManager};
use crate::mwccl::{CclError, ReduceOp, Work, WorldOptions};
use crate::runtime::StageRunner;
use crate::serving::router::ReplicaRouter;
use crate::tensor::{read_tensor, DType, Tensor};
use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

/// Data-plane tag (one logical stream per edge world; messages queue
/// FIFO under the tag).
pub const TAG_DATA: u64 = 1;

/// An in-flight unit: request-batch id + activation tensor, packed into
/// a U8 tensor so it rides the existing collectives.
#[derive(Clone, Debug, PartialEq)]
pub struct Envelope {
    pub id: u64,
    pub tensor: Tensor,
}

impl Envelope {
    pub fn pack(&self) -> Tensor {
        let mut bytes = Vec::with_capacity(8 + 64 + self.tensor.byte_len());
        bytes.extend_from_slice(&self.id.to_le_bytes());
        crate::tensor::write_tensor(&mut bytes, &self.tensor).expect("pack envelope");
        let n = bytes.len();
        Tensor::from_bytes(DType::U8, &[n], bytes).expect("pack envelope tensor")
    }

    pub fn unpack(t: &Tensor) -> anyhow::Result<Envelope> {
        anyhow::ensure!(t.dtype() == DType::U8, "envelope must be U8");
        let bytes = t.bytes();
        anyhow::ensure!(bytes.len() >= 8, "envelope too short");
        let id = u64::from_le_bytes(bytes[0..8].try_into().unwrap());
        let tensor = read_tensor(&mut &bytes[8..])?;
        Ok(Envelope { id, tensor })
    }
}

/// Control-plane messages to a running worker.
#[derive(Debug)]
pub enum TopoUpdate {
    /// Join a fresh world (online instantiation / scale-out / shard
    /// recovery — edge and TP worlds alike).
    AddWorld(WorldDef),
    /// Drain and exit.
    Shutdown,
}

/// Configuration for one worker node (one shard).
pub struct StageWorkerConfig {
    pub node: NodeId,
    pub topology: Topology,
    /// Stage executable; `None` = forward-only (transport benches and
    /// the artifact-less serving tests).
    pub stage: Option<Arc<StageRunner>>,
    pub opts: WorldOptions,
    /// Control channel (None = static topology).
    pub control: Option<Receiver<TopoUpdate>>,
    pub stop: Arc<AtomicBool>,
}

/// What a worker did during its run.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub processed: u64,
    pub forwarded: u64,
    /// TP rounds (broadcast + all_reduce) this shard took part in.
    pub tp_batches: u64,
    pub in_edge_failures: u64,
    pub out_edge_failures: u64,
    /// TP worlds this shard saw break underneath it.
    pub tp_failures: u64,
    pub joined_worlds: u64,
}

/// This shard's live membership in its replica's TP world.
#[derive(Clone)]
struct TpState {
    world: String,
    /// Rank == shard index (the head is rank 0 and drives the rounds).
    rank: usize,
    size: usize,
    /// Cached world handle for `last_algo` reads (skips the manager's
    /// registry lock on the per-batch path).
    handle: crate::mwccl::World,
    /// Pre-resolved `serving.tp.<op>.<algo>` counters (broadcast then
    /// all_reduce, flat then ring) — the per-batch observability is two
    /// atomic increments, no allocation, no registry lookup.
    algo_counters: [Arc<crate::metrics::Counter>; 4],
}

const TP_BCAST_FLAT: usize = 0;
const TP_AR_FLAT: usize = 2;

impl TpState {
    /// Resolve the TP state for a freshly joined (or startup-time) TP
    /// world; `None` when the world already vanished from the manager.
    fn resolve(mgr: &WorldManager, name: &str, rank: usize, size: usize) -> Option<TpState> {
        let handle = mgr.world(name).ok()?;
        let g = crate::metrics::global();
        Some(TpState {
            world: name.to_string(),
            rank,
            size,
            handle,
            algo_counters: [
                g.counter("serving.tp.broadcast.flat"),
                g.counter("serving.tp.broadcast.ring"),
                g.counter("serving.tp.all_reduce.flat"),
                g.counter("serving.tp.all_reduce.ring"),
            ],
        })
    }

    /// Record the algorithms the round's broadcast/all_reduce actually
    /// ran (from [`crate::mwccl::World::last_algo`]) — the observable
    /// proof that the serving hot path drives the collective selector.
    fn note_round_algos(&self) {
        if let Some(algo) = self.handle.last_algo(CollOp::Broadcast) {
            self.algo_counters[TP_BCAST_FLAT + usize::from(algo == "ring")].inc();
        }
        if let Some(algo) = self.handle.last_algo(CollOp::AllReduce) {
            self.algo_counters[TP_AR_FLAT + usize::from(algo == "ring")].inc();
        }
    }
}

/// Initialize this node's side of every world it belongs to, in
/// parallel (each `World::init` blocks until all members arrive).
pub fn init_node_worlds(
    mgr: &WorldManager,
    topo: &Topology,
    node: NodeId,
    opts: &WorldOptions,
) -> anyhow::Result<()> {
    let defs: Vec<WorldDef> = topo.worlds_of(node).into_iter().cloned().collect();
    let handles: Vec<_> = defs
        .into_iter()
        .map(|def| {
            let rank = def.rank_of(node).expect("member");
            let addr: SocketAddr = format!("127.0.0.1:{}", def.store_port).parse().unwrap();
            // A placed topology pins each world's rank→host picture so
            // the collective selector and the mux transport see the
            // same locality the deployment has.
            let opts = match topo.world_hostmap(&def) {
                Some(spec) => opts.clone().with_hostmap(&spec),
                None => opts.clone(),
            };
            mgr_init_async(mgr.clone(), def.name.clone(), rank, def.size(), addr, opts)
        })
        .collect();
    for h in handles {
        h.join()
            .map_err(|_| anyhow::anyhow!("init thread panicked"))??;
    }
    Ok(())
}

fn mgr_init_async(
    mgr: WorldManager,
    name: String,
    rank: usize,
    size: usize,
    addr: SocketAddr,
    opts: WorldOptions,
) -> std::thread::JoinHandle<Result<(), MwError>> {
    std::thread::Builder::new()
        .name(format!("init-{name}-r{rank}"))
        .spawn(move || mgr.initialize_world(&name, rank, size, addr, opts))
        .expect("spawn world init")
}

/// This shard's contribution to the TP combine: its weight slice's
/// partial output when a stage executable is loaded; in forward-only
/// mode, an f32 view of the activation scaled by `1/tp` (so the
/// all_reduce still moves real activation-sized payloads and sums to
/// the broadcast value for power-of-two `tp`).
fn shard_partial(
    stage: Option<&Arc<StageRunner>>,
    input: &Tensor,
    shard: usize,
    tp: usize,
) -> anyhow::Result<Tensor> {
    match stage {
        Some(s) => s.run_sharded(input, shard, tp),
        None => {
            let mut t = tensor_as_f32(input);
            t.scale(1.0 / tp as f32);
            Ok(t)
        }
    }
}

/// Flat f32 view of any tensor (forward-only TP combine input).
fn tensor_as_f32(t: &Tensor) -> Tensor {
    match t.dtype() {
        DType::F32 => t.clone(),
        DType::I32 => {
            let vals: Vec<f32> = t.as_i32().iter().map(|&v| v as f32).collect();
            Tensor::from_f32(&[vals.len()], &vals)
        }
        _ => {
            let vals: Vec<f32> = t.bytes().iter().map(|&b| b as f32).collect();
            Tensor::from_f32(&[vals.len()], &vals)
        }
    }
}

/// Wait for `work` with bounded polls so `stop` stays live. Returns
/// `None` when stopped before completion.
fn wait_work(
    comm: &WorldCommunicator,
    work: &Work,
    stop: &AtomicBool,
) -> Option<Result<Option<Tensor>, CclError>> {
    loop {
        if comm
            .wait_any_deadline(std::slice::from_ref(work), Some(Duration::from_millis(20)))
            .is_some()
        {
            return Some(work.wait());
        }
        if stop.load(Ordering::Relaxed) {
            return None;
        }
    }
}

/// One head-side TP round: broadcast the activation to the shards,
/// compute the head's own partial, all_reduce the partials. Returns the
/// combined output (or the original activation in forward-only mode,
/// where the combine payload is a cast — see [`shard_partial`]).
fn tp_head_round(
    comm: &WorldCommunicator,
    stage: Option<&Arc<StageRunner>>,
    tp: &TpState,
    input: &Tensor,
    stop: &AtomicBool,
) -> anyhow::Result<Option<Tensor>> {
    let bcast = comm
        .broadcast(&tp.world, Some(input.clone()), 0)
        .map_err(|e| anyhow::anyhow!("tp broadcast: {e}"))?;
    match wait_work(comm, &bcast, stop) {
        Some(Ok(_)) => {}
        Some(Err(e)) => anyhow::bail!("tp broadcast: {e}"),
        None => return Ok(None), // stopping
    }
    let partial = shard_partial(stage, input, tp.rank, tp.size)?;
    let reduce = comm
        .all_reduce(&tp.world, partial, ReduceOp::Sum)
        .map_err(|e| anyhow::anyhow!("tp all_reduce: {e}"))?;
    let reduced = match wait_work(comm, &reduce, stop) {
        Some(Ok(Some(t))) => t,
        Some(Ok(None)) => anyhow::bail!("tp all_reduce returned no tensor"),
        Some(Err(e)) => anyhow::bail!("tp all_reduce: {e}"),
        None => return Ok(None),
    };
    tp.note_round_algos();
    Ok(Some(match stage {
        Some(_) => reduced,
        // Forward-only: the combine moved a cast; forward the original
        // payload byte-exactly whatever its dtype.
        None => input.clone(),
    }))
}

/// Run the worker loop until `stop`, or until every data source (in-edge
/// or TP world) is gone and no control channel can bring more.
pub fn run_stage_worker(mgr: WorldManager, cfg: StageWorkerConfig) -> anyhow::Result<WorkerStats> {
    let comm = mgr.communicator();
    let events = mgr.subscribe();
    let mut stats = WorkerStats::default();

    // Live edge sets (heads only — non-head shards sit on no edges).
    let mut in_edges: Vec<String> = cfg
        .topology
        .in_edges(cfg.node)
        .iter()
        .map(|w| w.name.clone())
        .collect();
    let out_router = ReplicaRouter::new(0);
    for w in cfg.topology.out_edges(cfg.node) {
        out_router.add_replica(&w.name);
    }
    // This shard's TP world, if its replica is sharded (joined by
    // init_node_worlds before this loop starts, so the handle resolves).
    let mut tp: Option<TpState> = cfg.topology.tp_world_of(cfg.node).and_then(|w| {
        TpState::resolve(&mgr, &w.name, w.rank_of(cfg.node)?, w.size())
    });
    // A sharded replica must never compute without its shards: while the
    // TP world is down (shard death, awaiting the controller's fresh
    // worlds) the head drops incoming batches instead of serving solo.
    let sharded = match cfg.node {
        NodeId::Worker { stage, .. } => cfg.topology.tp_of(stage) > 1,
        NodeId::Leader => false,
    };

    // One posted irecv per live in-edge.
    let mut pending: HashMap<String, Work> = HashMap::new();
    for e in &in_edges {
        if let Ok(w) = comm.recv(e, 0, TAG_DATA) {
            pending.insert(e.clone(), w);
        }
    }
    // Non-head shards: the pending broadcast of the next TP round.
    let mut tp_pending: Option<Work> = None;
    // Slot-addressed running-batch state for step frames (continuous
    // batching). Heads only — followers see step payloads through the
    // ordinary TP broadcast and need no slot view.
    let mut decode_slots = crate::runtime::decode::DecodeSlots::default();
    let step_metrics = crate::metrics::global();
    let step_frames = step_metrics.counter("serving.worker.step_frames");
    let step_corrupt = step_metrics.counter("serving.worker.step_corrupt");

    let debug = std::env::var("MW_DEBUG").is_ok();
    let mut last_dbg = std::time::Instant::now();
    loop {
        if debug && last_dbg.elapsed() > Duration::from_secs(1) {
            last_dbg = std::time::Instant::now();
            eprintln!(
                "[worker {}] alive: in={:?} pending={} out={:?} tp={:?}",
                cfg.node,
                in_edges,
                pending.len(),
                out_router.alive_replicas(),
                tp.as_ref().map(|t| &t.world),
            );
        }
        if cfg.stop.load(Ordering::Relaxed) {
            break;
        }
        // Control-plane: join new worlds without stalling the data path.
        if let Some(ctrl) = &cfg.control {
            while let Ok(update) = ctrl.try_recv() {
                match update {
                    TopoUpdate::AddWorld(def) => {
                        let rank = match def.rank_of(cfg.node) {
                            Some(r) => r,
                            None => continue, // not our world
                        };
                        let addr: SocketAddr =
                            format!("127.0.0.1:{}", def.store_port).parse().unwrap();
                        // Blocking init is fine *here*: either the joiner
                        // is new and has no traffic yet, or (shard
                        // recovery) its TP world just broke and its data
                        // path is idle anyway. Existing members join via
                        // their own control threads concurrently. A
                        // failed join (the counterpart never came up)
                        // must not kill this worker — drop the world and
                        // keep serving whatever is still healthy.
                        let joined = mgr.initialize_world(
                            &def.name,
                            rank,
                            def.size(),
                            addr,
                            cfg.opts.clone(),
                        );
                        if let Err(e) = joined {
                            crate::metrics::global().counter("worker.join_failures").inc();
                            crate::metrics::log_event(
                                "worker.join_failed",
                                &[
                                    ("node", cfg.node.to_string().as_str()),
                                    ("world", def.name.as_str()),
                                    ("reason", e.to_string().as_str()),
                                ],
                            );
                            continue;
                        }
                        stats.joined_worlds += 1;
                        if def.is_tp() {
                            tp = TpState::resolve(&mgr, &def.name, rank, def.size());
                            tp_pending = None;
                        } else if rank == 1 {
                            in_edges.push(def.name.clone());
                            if let Ok(w) = comm.recv(&def.name, 0, TAG_DATA) {
                                pending.insert(def.name.clone(), w);
                            }
                        } else {
                            out_router.add_replica(&def.name);
                        }
                    }
                    TopoUpdate::Shutdown => {
                        cfg.stop.store(true, Ordering::Relaxed);
                    }
                }
            }
        }
        // Fault events: drop broken edges / the broken TP world.
        while let Ok(evt) = events.try_recv() {
            if let WorldEvent::Broken { world, .. } = evt {
                if tp.as_ref().is_some_and(|t| t.world == world) {
                    tp = None;
                    tp_pending = None;
                    stats.tp_failures += 1;
                } else if in_edges.contains(&world) {
                    in_edges.retain(|e| e != &world);
                    pending.remove(&world);
                    stats.in_edge_failures += 1;
                } else {
                    out_router.mark_dead(&world);
                    stats.out_edge_failures += 1;
                }
            }
        }

        if !cfg.node.is_head() {
            // ---------------- non-head shard: TP follower loop ----------
            // (Cloned so the broken-world paths can clear `tp` freely.)
            let Some(tps) = tp.clone() else {
                if cfg.control.is_none() {
                    break; // no TP world and no way to get a fresh one
                }
                std::thread::sleep(Duration::from_millis(2));
                continue;
            };
            if tp_pending.is_none() {
                match comm.broadcast(&tps.world, None, 0) {
                    Ok(w) => tp_pending = Some(w),
                    Err(_) => {
                        // World vanished between the event drain and now.
                        tp = None;
                        continue;
                    }
                }
            }
            let work = tp_pending.as_ref().unwrap().clone();
            if comm
                .wait_any_deadline(&[work.clone()], Some(Duration::from_millis(20)))
                .is_none()
            {
                continue; // nothing yet; loop to keep stop/control live
            }
            tp_pending = None;
            match work.wait() {
                Ok(Some(activation)) => {
                    // A failed sharded execution is a TP failure, not a
                    // worker death: break the world so peers unblock and
                    // the controller can re-mint it.
                    let partial = match shard_partial(
                        cfg.stage.as_ref(),
                        &activation,
                        tps.rank,
                        tps.size,
                    ) {
                        Ok(p) => p,
                        Err(e) => {
                            mgr.break_world(&tps.world, &e.to_string());
                            tp = None;
                            stats.tp_failures += 1;
                            continue;
                        }
                    };
                    let reduce = match comm.all_reduce(&tps.world, partial, ReduceOp::Sum) {
                        Ok(w) => w,
                        Err(_) => {
                            tp = None;
                            stats.tp_failures += 1;
                            continue;
                        }
                    };
                    match wait_work(&comm, &reduce, &cfg.stop) {
                        Some(Ok(_)) => {
                            stats.processed += 1;
                            stats.tp_batches += 1;
                        }
                        Some(Err(e)) => {
                            if e.is_fatal_to_world() {
                                mgr.break_world(&tps.world, &e.to_string());
                            }
                            tp = None;
                            stats.tp_failures += 1;
                        }
                        None => {}
                    }
                }
                Ok(None) => {}
                Err(e) => {
                    if debug {
                        eprintln!("[worker {}] tp broadcast failed: {e}", cfg.node);
                    }
                    if e.is_fatal_to_world() {
                        mgr.break_world(&tps.world, &e.to_string());
                    }
                    tp = None;
                    stats.tp_failures += 1;
                }
            }
            continue;
        }

        // ------------------------- head: edge-driven pipeline loop ------
        if pending.is_empty() {
            if cfg.control.is_none() && in_edges.is_empty() {
                break; // nothing will ever arrive again
            }
            std::thread::sleep(Duration::from_millis(2));
            continue;
        }

        // Poll for a completed receive (bounded so control/stop stay live).
        let names: Vec<String> = pending.keys().cloned().collect();
        let works: Vec<Work> = names.iter().map(|n| pending[n].clone()).collect();
        let Some(idx) = comm.wait_any_deadline(&works, Some(Duration::from_millis(20))) else {
            continue;
        };
        let edge = names[idx].clone();
        let work = pending.remove(&edge).unwrap();
        match work.wait() {
            Ok(Some(packed)) => {
                // Re-post the receive on this edge first (keep the pipe full).
                if let Ok(w) = comm.recv(&edge, 0, TAG_DATA) {
                    pending.insert(edge.clone(), w);
                }
                let env = Envelope::unpack(&packed)?;
                let result = if super::decode::StepFrame::is_step(&env.tensor) {
                    // ---- continuous-batching step frame ----
                    // Apply the leader's slot directives (idempotently —
                    // a retry resends the identical frame), run one
                    // decode iteration on the slot-packed payload, and
                    // forward the frame with the payload substituted.
                    let mut frame = match super::decode::StepFrame::unpack(&env.tensor) {
                        Ok(f) => f,
                        Err(_) => {
                            // A corrupt frame must never kill the worker:
                            // count it and let the leader's retry resend.
                            step_corrupt.inc();
                            continue;
                        }
                    };
                    step_frames.inc();
                    for e in &frame.entries {
                        match e.phase {
                            super::decode::StepPhase::Prefill => {
                                decode_slots.alloc(e.slot as usize, e.req_id, e.pos, e.budget);
                            }
                            super::decode::StepPhase::Decode => {
                                decode_slots.adopt(e.slot as usize, e.req_id, e.pos, e.budget);
                            }
                            super::decode::StepPhase::Retire => {
                                decode_slots.free(e.slot as usize);
                            }
                        }
                    }
                    let stepped = if let Some(tps) = tp.clone() {
                        // The TP round runs once per decode step, so the
                        // collective selector is exercised per iteration
                        // exactly as it is per one-shot batch.
                        match tp_head_round(
                            &comm,
                            cfg.stage.as_ref(),
                            &tps,
                            &frame.payload,
                            &cfg.stop,
                        ) {
                            Ok(Some(t)) => {
                                stats.tp_batches += 1;
                                decode_slots.advance();
                                t
                            }
                            Ok(None) => continue, // stopping mid-round
                            Err(e) => {
                                if debug {
                                    eprintln!(
                                        "[worker {}] tp step round failed: {e}",
                                        cfg.node
                                    );
                                }
                                mgr.break_world(&tps.world, &e.to_string());
                                tp = None;
                                stats.tp_failures += 1;
                                continue;
                            }
                        }
                    } else if sharded {
                        // TP world down: drop the frame; the leader
                        // resends after its retry timeout (directives are
                        // idempotent) or re-prefills elsewhere.
                        continue;
                    } else {
                        match &cfg.stage {
                            Some(stage) => {
                                stage.decode_step(&mut decode_slots, &frame.payload)?
                            }
                            None => {
                                // Forward-only: echo the payload, but the
                                // slot lifecycle still advances.
                                decode_slots.advance();
                                frame.payload.clone()
                            }
                        }
                    };
                    frame.payload = stepped;
                    frame.pack()
                } else if let Some(tps) = tp.clone() {
                    // TP inner loop: fan the activation out across the
                    // replica's shards, combine partial outputs.
                    match tp_head_round(&comm, cfg.stage.as_ref(), &tps, &env.tensor, &cfg.stop) {
                        Ok(Some(t)) => {
                            stats.tp_batches += 1;
                            t
                        }
                        Ok(None) => continue, // stopping mid-round
                        Err(e) => {
                            if debug {
                                eprintln!("[worker {}] tp round failed: {e}", cfg.node);
                            }
                            // The replica can't compute without its
                            // shards: break the TP world, abandon the
                            // batch (the leader re-dispatches it to a
                            // surviving replica after its retry timeout)
                            // and wait for the controller's fresh worlds.
                            mgr.break_world(&tps.world, &e.to_string());
                            tp = None;
                            stats.tp_failures += 1;
                            continue;
                        }
                    }
                } else if sharded {
                    // TP world down: the head alone holds only its own
                    // weight slice. Drop the batch; the leader retries
                    // it on a surviving replica, and the controller's
                    // fresh TP world restores this one.
                    continue;
                } else {
                    match &cfg.stage {
                        Some(stage) => stage.run(&env.tensor)?,
                        None => env.tensor, // forward-only mode
                    }
                };
                stats.processed += 1;
                // Route downstream, retrying across replicas on failure.
                let out = Envelope { id: env.id, tensor: result }.pack();
                loop {
                    let Some(token) = out_router.pick() else {
                        // No downstream alive: drop (leader will retry the batch).
                        break;
                    };
                    match comm.send_blocking(&token.replica, out.clone(), 1, TAG_DATA) {
                        Ok(()) => {
                            out_router.complete(&token);
                            stats.forwarded += 1;
                            break;
                        }
                        Err(_) => {
                            out_router.mark_dead(&token.replica);
                            stats.out_edge_failures += 1;
                        }
                    }
                }
            }
            Ok(None) => {}
            Err(e) => {
                if debug {
                    eprintln!("[worker {}] recv on {edge} failed: {e}", cfg.node);
                }
                // In-edge broke (remote error or watchdog abort).
                if matches!(
                    e,
                    CclError::RemoteError { .. }
                        | CclError::Aborted(_)
                        | CclError::WorldBroken(_)
                ) {
                    mgr.break_world(&edge, &e.to_string());
                    in_edges.retain(|x| x != &edge);
                    stats.in_edge_failures += 1;
                }
            }
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn envelope_roundtrip() {
        let mut rng = Rng::new(1);
        let t = Tensor::rand_f32(&[4, 8], &mut rng);
        let env = Envelope { id: 0xDEAD_BEEF, tensor: t.clone() };
        let packed = env.pack();
        assert_eq!(packed.dtype(), DType::U8);
        let back = Envelope::unpack(&packed).unwrap();
        assert_eq!(back.id, 0xDEAD_BEEF);
        assert_eq!(back.tensor.checksum(), t.checksum());
    }

    #[test]
    fn envelope_rejects_garbage() {
        let t = Tensor::from_bytes(DType::U8, &[4], vec![1, 2, 3, 4]).unwrap();
        assert!(Envelope::unpack(&t).is_err());
        let f = Tensor::zeros(DType::F32, &[4]);
        assert!(Envelope::unpack(&f).is_err());
    }

    #[test]
    fn envelope_empty_tensor() {
        let env = Envelope { id: 7, tensor: Tensor::zeros(DType::F32, &[0]) };
        let back = Envelope::unpack(&env.pack()).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.tensor.elems(), 0);
    }

    #[test]
    fn forward_only_partials_sum_back_to_the_activation() {
        // Power-of-two tp: Σ shard partials == the f32 activation, exactly.
        let t = Tensor::from_f32(&[8], &[1.0, -2.0, 3.5, 0.0, 7.25, 9.0, -4.5, 2.0]);
        let tp = 4;
        let mut acc = Tensor::zeros(DType::F32, &[8]);
        for shard in 0..tp {
            acc.add_assign(&shard_partial(None, &t, shard, tp).unwrap());
        }
        assert_eq!(acc.as_f32(), t.as_f32());
    }

    #[test]
    fn forward_only_partial_casts_non_f32() {
        let t = Tensor::from_i32(&[4], &[3, -1, 200, 0]);
        let p = shard_partial(None, &t, 0, 2).unwrap();
        assert_eq!(p.dtype(), DType::F32);
        assert_eq!(p.as_f32(), &[1.5, -0.5, 100.0, 0.0]);
    }
}
