//! Continuous batching: the step-frame wire protocol and the
//! iteration-level scheduler state behind the leader's decode loop.
//!
//! ## Why iteration-level scheduling
//!
//! The legacy hot path treats a request as exactly one forward pass:
//! the batcher fuses whoever arrived inside one admission window, the
//! batch runs to completion, and the next batch only forms afterwards.
//! For multi-token (decode) workloads that gang-scheduling wastes most
//! of the machine: a batch runs as long as its *longest* member, while
//! finished slots sit idle. The decode loop here re-schedules **every
//! iteration**: each decode step admits queued requests into free slots
//! (prefill) and retires finished or SLO-expired ones, so the running
//! batch stays full as long as there is work — the classic continuous
//! batching result (≈ the max-budget/mean-budget ratio in throughput).
//!
//! ## The wire protocol
//!
//! One **step frame** per pipeline lane per iteration, carried *inside*
//! the existing [`Envelope`](super::stage_worker::Envelope) as a U8
//! tensor with an 8-byte magic prefix, so legacy one-shot batches
//! (i32 `[B, S]` token tensors) and step frames share every transport
//! byte except the inner payload — `max_tokens = 1` deployments never
//! produce a frame and stay byte-identical to the pre-streaming
//! runtime. A frame carries:
//!
//! * per-slot directives ([`StepEntry`]): `Prefill` (bind the slot to a
//!   request and allocate KV state), `Decode` (advance the resident
//!   request), `Retire` (free the slot);
//! * the packed token payload (`[B, S]` i32; row *i* is slot *i*'s
//!   sliding window of prompt + generated tokens).
//!
//! Workers apply the directives to their slot-addressed
//! [`DecodeSlots`](crate::runtime::decode::DecodeSlots), run the stage
//! step-wise, substitute the output payload and forward the frame —
//! the leader's collector harvests one token per occupied slot per
//! frame. The **leader is the source of truth**: worker slot state is
//! soft, so a promoted spare adopts the next frame's directives from
//! empty state and evicted-by-failure requests **re-prefill** (their
//! prompt plus everything generated so far is replayed) instead of
//! being lost.

use super::request::Request;
use crate::tensor::{read_tensor, DType, Tensor};
use std::collections::{HashMap, VecDeque};
use std::time::Instant;

/// Magic prefix distinguishing a step frame from every legacy envelope
/// payload (which are i32/f32 tensors, never U8 starting with this).
pub const STEP_MAGIC: [u8; 8] = *b"MWSTEP1\0";

/// Slot directive carried by a [`StepEntry`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPhase {
    /// Bind the slot to this request and allocate decode state.
    Prefill,
    /// Advance the resident request one decode step.
    Decode,
    /// Free the slot (request finished or was evicted).
    Retire,
}

/// One slot's directive within a step frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepEntry {
    pub slot: u16,
    pub req_id: u64,
    /// Tokens generated so far (the decode position).
    pub pos: u32,
    /// Tokens still budgeted after this position.
    pub budget: u32,
    pub phase: StepPhase,
}

const ENTRY_BYTES: usize = 2 + 8 + 4 + 4 + 1;

/// One decode iteration on the wire. See module docs.
#[derive(Clone, Debug, PartialEq)]
pub struct StepFrame {
    pub entries: Vec<StepEntry>,
    /// `[B, S]` i32 on the leader→stage0 hop; whatever the stages
    /// produce on later hops (logits on the final one).
    pub payload: Tensor,
}

impl StepFrame {
    /// Cheap classifier: is this envelope payload a step frame?
    pub fn is_step(t: &Tensor) -> bool {
        t.dtype() == DType::U8 && t.bytes().len() >= 8 && t.bytes()[..8] == STEP_MAGIC
    }

    pub fn pack(&self) -> Tensor {
        let mut bytes = Vec::with_capacity(
            8 + 2 + self.entries.len() * ENTRY_BYTES + 64 + self.payload.byte_len(),
        );
        bytes.extend_from_slice(&STEP_MAGIC);
        bytes.extend_from_slice(&(self.entries.len() as u16).to_le_bytes());
        for e in &self.entries {
            bytes.extend_from_slice(&e.slot.to_le_bytes());
            bytes.extend_from_slice(&e.req_id.to_le_bytes());
            bytes.extend_from_slice(&e.pos.to_le_bytes());
            bytes.extend_from_slice(&e.budget.to_le_bytes());
            bytes.push(match e.phase {
                StepPhase::Prefill => 0,
                StepPhase::Decode => 1,
                StepPhase::Retire => 2,
            });
        }
        crate::tensor::write_tensor(&mut bytes, &self.payload).expect("pack step frame");
        let n = bytes.len();
        Tensor::from_bytes(DType::U8, &[n], bytes).expect("step frame tensor")
    }

    pub fn unpack(t: &Tensor) -> anyhow::Result<StepFrame> {
        anyhow::ensure!(Self::is_step(t), "not a step frame");
        let bytes = t.bytes();
        anyhow::ensure!(bytes.len() >= 10, "step frame too short");
        let count = u16::from_le_bytes(bytes[8..10].try_into().unwrap()) as usize;
        let mut off = 10;
        anyhow::ensure!(
            bytes.len() >= off + count * ENTRY_BYTES,
            "step frame truncated: {} entries claimed",
            count
        );
        let mut entries = Vec::with_capacity(count);
        for _ in 0..count {
            let slot = u16::from_le_bytes(bytes[off..off + 2].try_into().unwrap());
            let req_id = u64::from_le_bytes(bytes[off + 2..off + 10].try_into().unwrap());
            let pos = u32::from_le_bytes(bytes[off + 10..off + 14].try_into().unwrap());
            let budget = u32::from_le_bytes(bytes[off + 14..off + 18].try_into().unwrap());
            let phase = match bytes[off + 18] {
                0 => StepPhase::Prefill,
                1 => StepPhase::Decode,
                2 => StepPhase::Retire,
                other => anyhow::bail!("step frame: bad phase byte {other}"),
            };
            entries.push(StepEntry { slot, req_id, pos, budget, phase });
            off += ENTRY_BYTES;
        }
        let payload = read_tensor(&mut &bytes[off..])?;
        Ok(StepFrame { entries, payload })
    }
}

/// Deterministic stand-in token for forward-only pipelines (the echoed
/// payload carries no logits to argmax): a splitmix64 hash of
/// (request id, position) folded into the vocab, so streams are
/// reproducible across retries and re-prefills.
pub fn token_hash(req_id: u64, pos: u32, vocab: usize) -> i32 {
    let mut z = req_id ^ ((pos as u64) << 32) ^ 0x9E37_79B9_7F4A_7C15;
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z % vocab.max(1) as u64) as i32
}

/// One request resident in (or between) decode slots.
pub(crate) struct ActiveReq {
    pub req: Request,
    /// Total decode budget (tokens to generate).
    pub budget: u32,
    /// Tokens generated so far, in order.
    pub generated: Vec<i32>,
    /// Whether the worker side has (as far as we know) prefilled this
    /// request — `false` forces a `Prefill` directive on the next frame
    /// (fresh admission, or re-admission after its lane died).
    pub prefilled: bool,
    /// When the first token came back (epoch seconds); `None` until
    /// then. Drives the TTFT SLO and the TTFT metric.
    pub first_token_at: Option<f64>,
    /// When the most recent token came back (epoch seconds). Drives the
    /// inter-token-gap SLO and the ITL metric.
    pub last_token_at: f64,
}

impl ActiveReq {
    pub fn new(req: Request) -> ActiveReq {
        let budget = req.max_tokens.max(1);
        let arrival = req.arrival;
        ActiveReq {
            req,
            budget,
            generated: Vec::new(),
            prefilled: false,
            first_token_at: None,
            last_token_at: arrival,
        }
    }

    /// Tokens still to generate.
    pub fn remaining(&self) -> u32 {
        self.budget.saturating_sub(self.generated.len() as u32)
    }

    /// The request's current token window: the last `seq_len` tokens of
    /// prompt ++ generated. This is both the decode-step input and the
    /// **re-prefill** input after a failure — generated tokens are
    /// leader-side state, so a dead worker costs re-computation, never
    /// the request.
    pub fn window(&self, seq_len: usize) -> Vec<i32> {
        let total = self.req.tokens.len() + self.generated.len();
        let skip = total.saturating_sub(seq_len);
        self.req
            .tokens
            .iter()
            .chain(self.generated.iter())
            .skip(skip)
            .copied()
            .collect()
    }
}

/// A frame in flight on one lane.
pub(crate) struct Inflight {
    pub iter: u64,
    pub sent_at: Instant,
    pub attempts: u32,
    /// The packed envelope, kept so a retry resends the *identical*
    /// frame (worker-side directive application is idempotent).
    pub env: Tensor,
}

/// One pipeline lane: the decode loop's view of one stage-0 in-edge —
/// its running batch (slot-addressed), the frame in flight on it (at
/// most one; the iteration stream is a pipeline of depth 1 per lane),
/// and slots retired since the last frame (their `Retire` directives
/// ride the next one).
pub(crate) struct Lane {
    pub edge: String,
    pub slots: Vec<Option<ActiveReq>>,
    pub inflight: Option<Inflight>,
    pub retiring: Vec<(u16, u64)>,
}

impl Lane {
    pub fn new(edge: String, batch: usize) -> Lane {
        Lane {
            edge,
            slots: (0..batch).map(|_| None).collect(),
            inflight: None,
            retiring: Vec::new(),
        }
    }

    pub fn occupied(&self) -> usize {
        self.slots.iter().flatten().count()
    }
}

/// The decode scheduler's shared state: lanes keyed by in-edge name
/// plus the re-admission queue for requests whose lane died (they
/// re-prefill on the next lane with a free slot, ahead of fresh
/// arrivals). Pure bookkeeping — the leader drives it and owns all
/// I/O.
pub(crate) struct DecodeState {
    pub lanes: HashMap<String, Lane>,
    pub requeue: VecDeque<ActiveReq>,
    batch: usize,
}

impl DecodeState {
    pub fn new(batch: usize) -> DecodeState {
        DecodeState { lanes: HashMap::new(), requeue: VecDeque::new(), batch }
    }

    /// Reconcile lanes against the router's live in-edge set: dead or
    /// retired edges requeue their residents (re-prefill elsewhere),
    /// fresh edges (scale-out, recovery re-mint) get empty lanes.
    pub fn sync_lanes(&mut self, alive: &[String]) {
        let gone: Vec<String> = self
            .lanes
            .keys()
            .filter(|e| !alive.iter().any(|a| a == *e))
            .cloned()
            .collect();
        for e in gone {
            self.kill_lane(&e);
        }
        for e in alive {
            if !self.lanes.contains_key(e) {
                self.lanes.insert(e.clone(), Lane::new(e.clone(), self.batch));
            }
        }
    }

    /// Tear a lane down, requeueing every resident for re-prefill. The
    /// in-flight frame (if any) is simply dropped — its requests are
    /// the residents being requeued, so nothing is lost.
    pub fn kill_lane(&mut self, edge: &str) {
        if let Some(lane) = self.lanes.remove(edge) {
            for mut a in lane.slots.into_iter().flatten() {
                a.prefilled = false;
                self.requeue.push_back(a);
            }
        }
    }

    /// Requests resident in slots or waiting to re-admit (the decode
    /// side of the leader's outstanding-work signal).
    pub fn in_flight(&self) -> usize {
        self.requeue.len()
            + self
                .lanes
                .values()
                .map(|l| l.occupied() + usize::from(l.inflight.is_some()))
                .sum::<usize>()
    }
}

/// Pack the per-slot token windows into the `[B, S]` step payload.
/// Empty slots are zero rows (workers compute them, the leader ignores
/// them — slot addressing must stay positional).
pub(crate) fn pack_step_rows(
    slots: &[Option<ActiveReq>],
    batch: usize,
    seq_len: usize,
) -> Tensor {
    let mut tokens = vec![0i32; batch * seq_len];
    for (i, slot) in slots.iter().enumerate().take(batch) {
        if let Some(a) = slot {
            let w = a.window(seq_len);
            let row = &mut tokens[i * seq_len..(i + 1) * seq_len];
            // A short window (prompt shorter than seq_len) left-pads
            // with zeros so the most recent token sits at the end —
            // where the next-token logit is read.
            let off = seq_len.saturating_sub(w.len());
            row[off..].copy_from_slice(&w[w.len().saturating_sub(seq_len)..]);
        }
    }
    Tensor::from_i32(&[batch, seq_len], &tokens)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(slot: u16, req_id: u64, phase: StepPhase) -> StepEntry {
        StepEntry { slot, req_id, pos: 3, budget: 5, phase }
    }

    #[test]
    fn step_frame_roundtrip() {
        let payload = Tensor::from_i32(&[2, 4], &[1, 2, 3, 4, 5, 6, 7, 8]);
        let f = StepFrame {
            entries: vec![
                entry(0, 100, StepPhase::Prefill),
                entry(1, 200, StepPhase::Decode),
                entry(3, 300, StepPhase::Retire),
            ],
            payload: payload.clone(),
        };
        let packed = f.pack();
        assert!(StepFrame::is_step(&packed));
        let back = StepFrame::unpack(&packed).unwrap();
        assert_eq!(back, f);
        assert_eq!(back.payload.as_i32(), payload.as_i32());
    }

    #[test]
    fn legacy_payloads_are_not_step_frames() {
        // The exact tensors the legacy wire carries: i32 token batches
        // and f32 activations — and even a U8 tensor without the magic.
        assert!(!StepFrame::is_step(&Tensor::from_i32(&[2, 4], &[0; 8])));
        assert!(!StepFrame::is_step(&Tensor::zeros(DType::F32, &[8])));
        let u8t = Tensor::from_bytes(DType::U8, &[9], vec![7; 9]).unwrap();
        assert!(!StepFrame::is_step(&u8t));
        assert!(StepFrame::unpack(&u8t).is_err());
    }

    #[test]
    fn truncated_frames_are_rejected_not_panicked() {
        let f = StepFrame {
            entries: vec![entry(0, 1, StepPhase::Decode)],
            payload: Tensor::from_i32(&[1, 2], &[1, 2]),
        };
        let packed = f.pack();
        let bytes = packed.bytes();
        for cut in [9, 12, 20] {
            let t = Tensor::from_bytes(DType::U8, &[cut], bytes[..cut].to_vec()).unwrap();
            assert!(StepFrame::unpack(&t).is_err(), "cut at {cut} must error");
        }
        // Bad phase byte.
        let mut corrupt = bytes.to_vec();
        corrupt[10 + ENTRY_BYTES - 1] = 9;
        let n = corrupt.len();
        let t = Tensor::from_bytes(DType::U8, &[n], corrupt).unwrap();
        assert!(StepFrame::unpack(&t).is_err());
    }

    #[test]
    fn token_hash_is_deterministic_and_in_vocab() {
        for vocab in [1usize, 2, 32, 50_000] {
            for pos in 0..8u32 {
                let a = token_hash(42, pos, vocab);
                assert_eq!(a, token_hash(42, pos, vocab));
                assert!((0..vocab as i32).contains(&a));
            }
        }
        assert_ne!(
            token_hash(1, 0, 50_000),
            token_hash(2, 0, 50_000),
            "different requests stream different tokens"
        );
    }

    fn active(id: u64, prompt: &[i32], budget: u32) -> ActiveReq {
        ActiveReq::new(Request::new(id, prompt.to_vec()).with_max_tokens(budget))
    }

    #[test]
    fn window_slides_over_prompt_plus_generated() {
        let mut a = active(1, &[10, 11, 12, 13], 8);
        assert_eq!(a.window(4), vec![10, 11, 12, 13]);
        a.generated.extend([20, 21]);
        assert_eq!(a.window(4), vec![12, 13, 20, 21], "generated tokens shift in");
        assert_eq!(a.window(8), vec![10, 11, 12, 13, 20, 21], "short window keeps all");
        assert_eq!(a.remaining(), 6);
    }

    #[test]
    fn pack_step_rows_is_positional_with_zero_padding() {
        let mut slots: Vec<Option<ActiveReq>> = vec![None, None, None];
        slots[1] = Some(active(7, &[1, 2], 4));
        let t = pack_step_rows(&slots, 3, 4);
        assert_eq!(t.shape(), &[3, 4]);
        let rows = t.as_i32();
        assert_eq!(&rows[0..4], &[0, 0, 0, 0], "empty slot row is zeros");
        assert_eq!(&rows[4..8], &[0, 0, 1, 2], "short prompt left-pads");
        assert_eq!(&rows[8..12], &[0, 0, 0, 0]);
    }

    #[test]
    fn kill_lane_requeues_residents_for_reprefill() {
        let mut st = DecodeState::new(2);
        st.sync_lanes(&["in-a".into(), "in-b".into()]);
        assert_eq!(st.lanes.len(), 2);
        let lane = st.lanes.get_mut("in-a").unwrap();
        let mut a = active(5, &[1, 2, 3], 6);
        a.generated.extend([9, 8]);
        a.prefilled = true;
        lane.slots[1] = Some(a);
        assert_eq!(st.in_flight(), 1);
        // The lane's edge disappears (worker died / edge retired).
        st.sync_lanes(&["in-b".into()]);
        assert!(!st.lanes.contains_key("in-a"));
        assert_eq!(st.requeue.len(), 1, "resident survived the lane");
        let back = st.requeue.front().unwrap();
        assert!(!back.prefilled, "re-admission forces a fresh prefill");
        assert_eq!(back.generated, vec![9, 8], "generated tokens are leader state");
        assert_eq!(st.in_flight(), 1);
    }
}
