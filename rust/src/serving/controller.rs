//! The elasticity controller — the piece the paper explicitly leaves as
//! future work ("the design and implementation of a controller is out
//! of scope") and that we build as the extension deliverable.
//!
//! Responsibilities:
//!
//! * **Recovery**: when a worker dies (reported through broken edge
//!   worlds), mint a replacement replica with *fresh* worlds — broken
//!   world names are never reused — and orchestrate the join: existing
//!   members get [`TopoUpdate::AddWorld`] on their control channels, the
//!   new worker is spawned via the [`Spawner`].
//! * **Scale-out**: when the leader's queue depth per replica exceeds
//!   the policy threshold, add a replica to the bottleneck stage the
//!   same way (Fig. 2c).
//! * **Scale-in**: drain and retire a replica when utilization stays
//!   below the low-water mark.

use super::stage_worker::TopoUpdate;
use super::topology::{NodeId, Topology, WorldDef};
use crate::util::free_port;
use std::collections::{HashMap, HashSet};
use std::sync::mpsc::Sender;
use std::sync::Mutex;

/// Scaling policy knobs.
#[derive(Clone, Debug)]
pub struct ScalingPolicy {
    /// Queue depth per alive replica that triggers scale-out.
    pub scale_up_depth: f64,
    /// Ceiling on replicas per stage.
    pub max_replicas: usize,
    /// Respawn replacements for dead workers.
    pub recover: bool,
}

impl Default for ScalingPolicy {
    fn default() -> Self {
        ScalingPolicy { scale_up_depth: 16.0, max_replicas: 4, recover: true }
    }
}

/// How the controller materializes a new worker (thread in-process,
/// `multiworld worker` subprocess via the launcher).
pub trait Spawner: Send + Sync {
    /// Bring up `node`; it must join exactly `worlds`.
    fn spawn(&self, node: NodeId, worlds: Vec<WorldDef>) -> anyhow::Result<()>;
}

/// Decisions the controller took (test/bench introspection).
#[derive(Clone, Debug, PartialEq)]
pub enum Action {
    Recovered { dead: NodeId, replacement: NodeId },
    ScaledOut { stage: usize, node: NodeId },
    ScaledIn { node: NodeId },
}

/// See module docs.
pub struct Controller {
    topo: Mutex<Topology>,
    policy: ScalingPolicy,
    spawner: Box<dyn Spawner>,
    /// Control channels of running workers.
    worker_ctrl: Mutex<HashMap<NodeId, Sender<TopoUpdate>>>,
    /// Callback to join the leader's side of fresh worlds.
    leader_join: Box<dyn Fn(&WorldDef) -> anyhow::Result<()> + Send + Sync>,
    /// Nodes already declared dead (dedupe repeated reports).
    dead: Mutex<HashSet<NodeId>>,
    /// Broken-world strikes per worker: a node is declared dead only
    /// when *every* world it belongs to has been reported broken (its
    /// neighbors keep at least one healthy world, so they never qualify).
    strikes: Mutex<HashMap<NodeId, HashSet<String>>>,
    actions: Mutex<Vec<Action>>,
}

impl Controller {
    pub fn new(
        topo: Topology,
        policy: ScalingPolicy,
        spawner: Box<dyn Spawner>,
        leader_join: impl Fn(&WorldDef) -> anyhow::Result<()> + Send + Sync + 'static,
    ) -> Controller {
        Controller {
            topo: Mutex::new(topo),
            policy,
            spawner,
            worker_ctrl: Mutex::new(HashMap::new()),
            leader_join: Box::new(leader_join),
            dead: Mutex::new(HashSet::new()),
            strikes: Mutex::new(HashMap::new()),
            actions: Mutex::new(Vec::new()),
        }
    }

    /// Register a running worker's control channel.
    pub fn register_worker(&self, node: NodeId, tx: Sender<TopoUpdate>) {
        self.worker_ctrl.lock().unwrap().insert(node, tx);
    }

    pub fn topology(&self) -> Topology {
        self.topo.lock().unwrap().clone()
    }

    pub fn actions(&self) -> Vec<Action> {
        self.actions.lock().unwrap().clone()
    }

    /// A world broke somewhere in the pipeline. Both worker members get
    /// a strike; the worker whose *every* world is now reported broken
    /// is the dead one (its neighbors keep ≥1 healthy world). Dead
    /// workers are recovered once.
    pub fn on_world_broken(&self, world: &str) -> anyhow::Result<Option<Action>> {
        if std::env::var("MW_DEBUG").is_ok() {
            eprintln!("[controller] broken world reported: {world}");
        }
        let dead_node = {
            let topo = self.topo.lock().unwrap();
            let Some(def) = topo.worlds.iter().find(|w| w.name == world) else {
                return Ok(None); // already cleaned up
            };
            let members = def.members;
            let mut strikes = self.strikes.lock().unwrap();
            let mut verdict = None;
            for m in members {
                if let NodeId::Worker { .. } = m {
                    let set = strikes.entry(m).or_default();
                    set.insert(world.to_string());
                    let total = topo.worlds_of(m).len();
                    if total > 0 && set.len() >= total {
                        verdict = Some(m);
                    }
                }
            }
            verdict
        };
        let Some(dead_node) = dead_node else { return Ok(None) };
        self.strikes.lock().unwrap().remove(&dead_node);
        self.declare_dead(dead_node)
    }

    /// Declare a worker dead (explicit form used when the observer knows
    /// exactly who died, e.g. the launcher saw the process exit).
    pub fn declare_dead(&self, dead_node: NodeId) -> anyhow::Result<Option<Action>> {
        {
            let mut dead = self.dead.lock().unwrap();
            if !dead.insert(dead_node) {
                return Ok(None); // already handled
            }
        }
        let NodeId::Worker { stage, .. } = dead_node else {
            return Ok(None);
        };
        // Remove the corpse's worlds from the map.
        {
            let mut topo = self.topo.lock().unwrap();
            topo.remove_node(dead_node);
        }
        self.worker_ctrl.lock().unwrap().remove(&dead_node);
        if !self.policy.recover {
            return Ok(None);
        }
        let replacement = self.add_replica(stage)?;
        let action = Action::Recovered { dead: dead_node, replacement };
        self.actions.lock().unwrap().push(action.clone());
        Ok(Some(action))
    }

    /// Scaling check: call periodically with the leader's queue depth
    /// per replica.
    pub fn maybe_scale_out(&self, stage: usize, depth_per_replica: f64) -> anyhow::Result<Option<Action>> {
        if depth_per_replica < self.policy.scale_up_depth {
            return Ok(None);
        }
        {
            let topo = self.topo.lock().unwrap();
            if topo.replicas[stage] >= self.policy.max_replicas {
                return Ok(None);
            }
        }
        let node = self.add_replica(stage)?;
        let action = Action::ScaledOut { stage, node };
        self.actions.lock().unwrap().push(action.clone());
        Ok(Some(action))
    }

    /// The shared mint-and-join path (Fig. 2c online instantiation):
    /// 1. extend the topology with a new replica and fresh worlds;
    /// 2. tell every *existing* member to join its side (non-blocking
    ///    for their data planes — they init on their control threads);
    /// 3. spawn the new worker, which joins all its worlds.
    fn add_replica(&self, stage: usize) -> anyhow::Result<NodeId> {
        let (node, fresh) = {
            let mut topo = self.topo.lock().unwrap();
            let base = free_port();
            topo.add_replica(stage, base)
        };
        // Existing members first, so their rendezvous is already waiting
        // when the new worker arrives (paper: join takes ~20 ms).
        let ctrl = self.worker_ctrl.lock().unwrap();
        for def in &fresh {
            for member in def.members {
                if member == node {
                    continue;
                }
                match member {
                    NodeId::Leader => (self.leader_join)(def)?,
                    w => {
                        if let Some(tx) = ctrl.get(&w) {
                            let _ = tx.send(TopoUpdate::AddWorld(def.clone()));
                        }
                    }
                }
            }
        }
        drop(ctrl);
        self.spawner.spawn(node, fresh)?;
        Ok(node)
    }

    /// Retire a replica (scale-in): drain via Shutdown on its control
    /// channel and drop its worlds from the topology.
    pub fn scale_in(&self, node: NodeId) -> anyhow::Result<Option<Action>> {
        let removed = {
            let mut topo = self.topo.lock().unwrap();
            topo.remove_node(node)
        };
        if removed.is_empty() {
            return Ok(None);
        }
        if let Some(tx) = self.worker_ctrl.lock().unwrap().remove(&node) {
            let _ = tx.send(TopoUpdate::Shutdown);
        }
        let action = Action::ScaledIn { node };
        self.actions.lock().unwrap().push(action.clone());
        Ok(Some(action))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    struct CountingSpawner(Arc<AtomicUsize>);

    impl Spawner for CountingSpawner {
        fn spawn(&self, _node: NodeId, worlds: Vec<WorldDef>) -> anyhow::Result<()> {
            assert!(!worlds.is_empty());
            self.0.fetch_add(1, Ordering::SeqCst);
            Ok(())
        }
    }

    fn controller(policy: ScalingPolicy) -> (Controller, Arc<AtomicUsize>) {
        let spawned = Arc::new(AtomicUsize::new(0));
        let topo = Topology::pipeline("t", &[1, 2, 1], 31_000);
        let c = Controller::new(
            topo,
            policy,
            Box::new(CountingSpawner(spawned.clone())),
            |_def| Ok(()),
        );
        (c, spawned)
    }

    #[test]
    fn recovery_replaces_dead_worker_once() {
        let (c, spawned) = controller(ScalingPolicy::default());
        let p3 = NodeId::Worker { stage: 1, replica: 1 };
        // When P3 dies, BOTH of its edge worlds break (Fig. 2b). The
        // first report only strikes; the second proves P3 dead (its
        // neighbors still have healthy worlds elsewhere).
        let worlds: Vec<String> = c
            .topology()
            .worlds_of(p3)
            .iter()
            .map(|w| w.name.clone())
            .collect();
        assert_eq!(worlds.len(), 2);
        assert!(c.on_world_broken(&worlds[0]).unwrap().is_none());
        let action = c.on_world_broken(&worlds[1]).unwrap().unwrap();
        match action {
            Action::Recovered { dead, replacement } => {
                assert_eq!(dead, p3);
                assert_eq!(replacement, NodeId::Worker { stage: 1, replica: 2 });
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(spawned.load(Ordering::SeqCst), 1);
        // Duplicate reports (the second edge also broke) are no-ops.
        assert!(c.declare_dead(p3).unwrap().is_none());
        assert_eq!(spawned.load(Ordering::SeqCst), 1);
        // Topology: P3 gone, replacement wired to both live neighbors.
        // (`replicas` is an id allocator: r1 is burned, r2 minted.)
        let topo = c.topology();
        assert_eq!(topo.replicas, vec![1, 3, 1]);
        assert_eq!(topo.live_replicas(1), vec![0, 2]);
        assert!(topo.worlds_of(p3).is_empty());
        let repl = NodeId::Worker { stage: 1, replica: 2 };
        assert_eq!(topo.worlds_of(repl).len(), 2);
    }

    #[test]
    fn no_recovery_when_disabled() {
        let (c, spawned) =
            controller(ScalingPolicy { recover: false, ..Default::default() });
        let p2 = NodeId::Worker { stage: 1, replica: 0 };
        assert!(c.declare_dead(p2).unwrap().is_none());
        assert_eq!(spawned.load(Ordering::SeqCst), 0);
        assert!(c.topology().worlds_of(p2).is_empty(), "corpse still removed");
    }

    #[test]
    fn scale_out_on_depth_threshold() {
        let (c, spawned) = controller(ScalingPolicy {
            scale_up_depth: 10.0,
            max_replicas: 3,
            recover: true,
        });
        assert!(c.maybe_scale_out(1, 5.0).unwrap().is_none(), "below threshold");
        let action = c.maybe_scale_out(1, 12.0).unwrap().unwrap();
        assert!(matches!(action, Action::ScaledOut { stage: 1, .. }));
        assert_eq!(spawned.load(Ordering::SeqCst), 1);
        assert_eq!(c.topology().replicas, vec![1, 3, 1]);
        // Ceiling respected.
        assert!(c.maybe_scale_out(1, 100.0).unwrap().is_none());
    }

    #[test]
    fn scale_in_retires_node() {
        let (c, _) = controller(ScalingPolicy::default());
        let node = NodeId::Worker { stage: 1, replica: 1 };
        let (tx, rx) = std::sync::mpsc::channel();
        c.register_worker(node, tx);
        let action = c.scale_in(node).unwrap().unwrap();
        assert_eq!(action, Action::ScaledIn { node });
        assert!(matches!(rx.try_recv(), Ok(TopoUpdate::Shutdown)));
        assert!(c.topology().worlds_of(node).is_empty());
        // Second scale_in is a no-op.
        assert!(c.scale_in(node).unwrap().is_none());
    }

    #[test]
    fn existing_members_receive_add_world() {
        let (c, _) = controller(ScalingPolicy::default());
        let p1 = NodeId::Worker { stage: 0, replica: 0 };
        let p4 = NodeId::Worker { stage: 2, replica: 0 };
        let (tx1, rx1) = std::sync::mpsc::channel();
        let (tx4, rx4) = std::sync::mpsc::channel();
        c.register_worker(p1, tx1);
        c.register_worker(p4, tx4);
        c.maybe_scale_out(1, 1e9).unwrap().unwrap();
        // P1 gets the upstream edge, P4 the downstream edge.
        assert!(matches!(rx1.try_recv(), Ok(TopoUpdate::AddWorld(_))));
        assert!(matches!(rx4.try_recv(), Ok(TopoUpdate::AddWorld(_))));
    }
}
