//! The elasticity controller — the piece the paper explicitly leaves as
//! future work ("the design and implementation of a controller is out
//! of scope") and that we build as the extension deliverable.
//!
//! Responsibilities:
//!
//! * **Recovery**: when a worker dies (reported through broken worlds),
//!   restore service with *fresh* worlds — broken world names are never
//!   reused — and orchestrate the join: existing members get
//!   [`TopoUpdate::AddWorld`] on their control channels, replacements
//!   are spawned via the [`Spawner`]. Fault domains are
//!   **shard-granular**: a dead shard of a tensor-parallel replica
//!   breaks its replica's TP world (plus the head's edge worlds when
//!   the head died); recovery re-mints the replica's broken world set
//!   under the same replica/shard ids and respawns *only the dead
//!   shard* — its TP neighbors and edge peers rejoin over their control
//!   channels. Unsharded (`tp = 1`) replicas keep the original
//!   behavior: the replica id is burned and a whole new replica is
//!   minted.
//! * **Scale-out**: when the leader's queue depth per replica exceeds
//!   the policy threshold, add a replica (all `tp` shards of it) to the
//!   bottleneck stage the same way (Fig. 2c).
//! * **Scale-in**: drain and retire a replica when utilization stays
//!   below the low-water mark.
//!
//! **Who died?** Failure signals arrive per *world*. When the signal
//! carries a culprit rank (watchdog missed-heartbeat alerts, TCP
//! `RemoteError`s — see [`crate::multiworld::WorldEvent::Broken`]) the
//! dead worker is `members[culprit]`, directly. Without attribution the
//! controller falls back to strike inference: a worker is declared dead
//! only when *every* world it belongs to has been reported broken *and*
//! at least one of those is an edge world. The edge-evidence clause is
//! what keeps TP neighbors alive: when a head dies, a non-head shard's
//! only world (the TP world) breaks too, so TP-world-only evidence is
//! never enough to convict — exactly one of the replica's shards is at
//! fault, and only the attributed signal can say which.

use super::stage_worker::TopoUpdate;
use super::topology::{NodeId, Topology, WorldDef};
use crate::util::free_port;
use std::collections::{HashMap, HashSet};
use std::sync::mpsc::Sender;
use std::sync::Mutex;

/// Scaling policy knobs.
#[derive(Clone, Debug)]
pub struct ScalingPolicy {
    /// Queue depth per alive replica that triggers scale-out.
    pub scale_up_depth: f64,
    /// Ceiling on replicas per stage.
    pub max_replicas: usize,
    /// Respawn replacements for dead workers.
    pub recover: bool,
}

impl Default for ScalingPolicy {
    fn default() -> Self {
        ScalingPolicy { scale_up_depth: 16.0, max_replicas: 4, recover: true }
    }
}

/// How the controller materializes a new worker (thread in-process,
/// `multiworld worker` subprocess via the launcher).
pub trait Spawner: Send + Sync {
    /// Bring up `node`; it must join exactly the worlds in `worlds` it
    /// is a member of.
    fn spawn(&self, node: NodeId, worlds: Vec<WorldDef>) -> anyhow::Result<()>;
}

/// Read-only view of the launcher's pre-warmed spare pool (`MW_SPARES`).
/// The controller itself never touches the pool — promotion happens
/// transparently inside the [`Spawner`] — but the autoscaler asks for
/// headroom through this view: with a warm spare standing by, scale-out
/// is promote-then-backfill instead of a cold spawn, so the policy can
/// afford to act sooner.
pub trait SparePoolView: Send + Sync {
    /// Spares currently warm and assignable.
    fn available(&self) -> usize;
}

/// Decisions the controller took (test/bench introspection).
#[derive(Clone, Debug, PartialEq)]
pub enum Action {
    /// `replacement == dead` for shard-granularity recovery (the shard
    /// id survives; only its worlds are fresh).
    Recovered { dead: NodeId, replacement: NodeId },
    ScaledOut { stage: usize, node: NodeId },
    ScaledIn { node: NodeId },
}

/// See module docs.
pub struct Controller {
    topo: Mutex<Topology>,
    policy: ScalingPolicy,
    spawner: Box<dyn Spawner>,
    /// Control channels of running workers.
    worker_ctrl: Mutex<HashMap<NodeId, Sender<TopoUpdate>>>,
    /// Callback to join the leader's side of fresh worlds.
    leader_join: Box<dyn Fn(&WorldDef) -> anyhow::Result<()> + Send + Sync>,
    /// Nodes already declared dead (dedupe repeated reports). Shard ids
    /// revived by shard recovery are removed again once respawned.
    dead: Mutex<HashSet<NodeId>>,
    /// Broken-world strikes per worker, for unattributed reports (see
    /// module docs for the conviction rule).
    strikes: Mutex<HashMap<NodeId, HashSet<String>>>,
    actions: Mutex<Vec<Action>>,
    /// Launcher's spare pool, when one exists (see [`SparePoolView`]).
    spare_pool: Mutex<Option<std::sync::Arc<dyn SparePoolView>>>,
}

impl Controller {
    pub fn new(
        topo: Topology,
        policy: ScalingPolicy,
        spawner: Box<dyn Spawner>,
        leader_join: impl Fn(&WorldDef) -> anyhow::Result<()> + Send + Sync + 'static,
    ) -> Controller {
        Controller {
            topo: Mutex::new(topo),
            policy,
            spawner,
            worker_ctrl: Mutex::new(HashMap::new()),
            leader_join: Box::new(leader_join),
            dead: Mutex::new(HashSet::new()),
            strikes: Mutex::new(HashMap::new()),
            actions: Mutex::new(Vec::new()),
            spare_pool: Mutex::new(None),
        }
    }

    /// Register a running worker's control channel.
    pub fn register_worker(&self, node: NodeId, tx: Sender<TopoUpdate>) {
        self.worker_ctrl.lock().unwrap().insert(node, tx);
    }

    /// Wire up the launcher's spare pool (once, at cluster start).
    pub fn set_spare_pool(&self, pool: std::sync::Arc<dyn SparePoolView>) {
        *self.spare_pool.lock().unwrap() = Some(pool);
    }

    /// Warm spares currently assignable — the autoscaler treats this as
    /// scale-out headroom (0 when no pool is configured).
    pub fn spare_headroom(&self) -> usize {
        self.spare_pool
            .lock()
            .unwrap()
            .as_ref()
            .map(|p| p.available())
            .unwrap_or(0)
    }

    pub fn topology(&self) -> Topology {
        self.topo.lock().unwrap().clone()
    }

    pub fn actions(&self) -> Vec<Action> {
        self.actions.lock().unwrap().clone()
    }

    /// A world broke somewhere in the pipeline. `culprit` is the rank
    /// the failure signal blamed, when it could (see module docs); with
    /// it the dead worker is identified directly, without it the report
    /// lands as a strike and the conviction rule decides. Dead workers
    /// are recovered once per incident.
    pub fn on_world_broken(
        &self,
        world: &str,
        culprit: Option<usize>,
    ) -> anyhow::Result<Option<Action>> {
        crate::metrics::global().counter("controller.broken_reports").inc();
        let culprit_s = culprit.map(|c| c.to_string()).unwrap_or_else(|| "-".into());
        crate::metrics::log_event(
            "controller.world_broken",
            &[("world", world), ("culprit_rank", culprit_s.as_str())],
        );
        let dead_node = {
            let topo = self.topo.lock().unwrap();
            let Some(def) = topo.worlds.iter().find(|w| w.name == world) else {
                return Ok(None); // already cleaned up
            };
            if let Some(rank) = culprit {
                match def.members.get(rank).copied() {
                    Some(m @ NodeId::Worker { .. }) => Some(m),
                    // The leader (or a bogus rank) — nothing to recover.
                    _ => return Ok(None),
                }
            } else {
                let mut strikes = self.strikes.lock().unwrap();
                let mut verdict = None;
                for &m in &def.members {
                    if let NodeId::Worker { .. } = m {
                        let set = strikes.entry(m).or_default();
                        set.insert(world.to_string());
                        let worlds = topo.worlds_of(m);
                        let all_broken =
                            !worlds.is_empty() && worlds.iter().all(|w| set.contains(&w.name));
                        let edge_evidence =
                            worlds.iter().any(|w| !w.is_tp() && set.contains(&w.name));
                        if all_broken && edge_evidence {
                            verdict = Some(m);
                        }
                    }
                }
                verdict
            }
        };
        let Some(dead_node) = dead_node else { return Ok(None) };
        self.strikes.lock().unwrap().remove(&dead_node);
        self.declare_dead(dead_node)
    }

    /// Declare a worker dead (explicit form used when the observer knows
    /// exactly who died, e.g. the launcher saw the process exit).
    pub fn declare_dead(&self, dead_node: NodeId) -> anyhow::Result<Option<Action>> {
        {
            let mut dead = self.dead.lock().unwrap();
            if !dead.insert(dead_node) {
                return Ok(None); // already handled
            }
        }
        let NodeId::Worker { stage, .. } = dead_node else {
            return Ok(None);
        };
        self.worker_ctrl.lock().unwrap().remove(&dead_node);
        let sharded = self.topo.lock().unwrap().tp_of(stage) > 1;
        if !self.policy.recover {
            // Still remove the corpse's broken worlds from the map.
            let removed = self.topo.lock().unwrap().remove_node(dead_node);
            self.purge_strikes(&removed);
            return Ok(None);
        }
        // MTTR span: verdict → replacement spawned + leader rejoined.
        // (The detection latency before the verdict is the watchdog's
        // budget; this window isolates what the recovery path itself
        // costs — the part spares + the weight cache drive toward zero.)
        let recovery_start = std::time::Instant::now();
        let replacement = if sharded {
            self.recover_shard(dead_node)?
        } else {
            let removed = self.topo.lock().unwrap().remove_node(dead_node);
            self.purge_strikes(&removed);
            self.mint_replica(stage)?
        };
        crate::metrics::global()
            .window("serving.mttr_ms")
            .observe(recovery_start.elapsed());
        crate::metrics::global().counter("controller.recoveries").inc();
        crate::metrics::log_event(
            "controller.recovered",
            &[
                ("dead", dead_node.to_string().as_str()),
                ("replacement", replacement.to_string().as_str()),
            ],
        );
        let action = Action::Recovered { dead: dead_node, replacement };
        self.actions.lock().unwrap().push(action.clone());
        Ok(Some(action))
    }

    /// Scaling check: call periodically with the leader's queue depth
    /// per replica.
    pub fn maybe_scale_out(&self, stage: usize, depth_per_replica: f64) -> anyhow::Result<Option<Action>> {
        if depth_per_replica < self.policy.scale_up_depth {
            return Ok(None);
        }
        {
            let topo = self.topo.lock().unwrap();
            if topo.replicas[stage] >= self.policy.max_replicas {
                return Ok(None);
            }
        }
        let node = self.mint_replica(stage)?;
        crate::metrics::global().counter("controller.scale_outs").inc();
        crate::metrics::log_event(
            "controller.scaled_out",
            &[
                ("stage", stage.to_string().as_str()),
                ("node", node.to_string().as_str()),
                ("depth_per_replica", format!("{depth_per_replica:.1}").as_str()),
            ],
        );
        let action = Action::ScaledOut { stage, node };
        self.actions.lock().unwrap().push(action.clone());
        Ok(Some(action))
    }

    /// The shared mint-and-join path (Fig. 2c online instantiation):
    /// 1. extend the topology with a new replica (all `tp` shards of
    ///    it) and fresh worlds;
    /// 2. tell every *existing* member to join its side (non-blocking
    ///    for their data planes — they init on their control threads);
    /// 3. spawn the new replica's shards, which join all their worlds.
    fn mint_replica(&self, stage: usize) -> anyhow::Result<NodeId> {
        let (node, fresh, tp) = {
            let mut topo = self.topo.lock().unwrap();
            let base = free_port();
            let (node, fresh) = topo.add_replica(stage, base);
            (node, fresh, topo.tp_of(stage))
        };
        let NodeId::Worker { replica, .. } = node else { unreachable!("worker minted") };
        // Existing workers first, so their rendezvous is already waiting
        // when the new workers arrive (paper: join takes ~20 ms). The
        // new replica's shards are excluded — they are spawned below.
        self.notify_workers(&fresh, |m| m.in_replica(stage, replica));
        for shard in 0..tp {
            self.spawner
                .spawn(NodeId::Worker { stage, replica, shard }, fresh.clone())?;
        }
        // The leader last: its join blocks until the world forms, so the
        // counterpart worker must already be spawning (first/last-stage
        // edges would deadlock otherwise).
        self.join_leader(&fresh)?;
        Ok(node)
    }

    /// Shard-granularity recovery: the replica and shard ids survive;
    /// only the replica's *broken* worlds (the TP world, plus the
    /// head's edges when the head died) are re-minted with fresh
    /// generation-tagged names. Surviving shards and edge peers rejoin
    /// over their control channels; only the dead shard is respawned.
    fn recover_shard(&self, dead_shard: NodeId) -> anyhow::Result<NodeId> {
        let (removed, fresh) = {
            let mut topo = self.topo.lock().unwrap();
            let base = free_port();
            topo.remint_replica(dead_shard, base)
        };
        self.purge_strikes(&removed);
        self.notify_workers(&fresh, |m| m == dead_shard);
        let result = self
            .spawner
            .spawn(dead_shard, fresh.clone())
            .and_then(|()| self.join_leader(&fresh));
        // The shard id lives again (or may legitimately die/fail again):
        // clear the dedupe entry even when the respawn failed, so a later
        // report can retry recovery instead of hitting the
        // "already handled" early-return forever. (Duplicate reports of
        // *this* incident reference the removed world names and are
        // dropped as "already cleaned up".)
        self.dead.lock().unwrap().remove(&dead_shard);
        if let Err(e) = result {
            crate::metrics::global().counter("controller.recovery_failures").inc();
            crate::metrics::log_event(
                "controller.recovery_failed",
                &[
                    ("dead", dead_shard.to_string().as_str()),
                    ("error", e.to_string().as_str()),
                ],
            );
            return Err(e);
        }
        Ok(dead_shard)
    }

    /// Ask every existing *worker* member of `fresh` to join its side
    /// (a non-blocking channel send — they init on their control
    /// paths), skipping members matched by `exclude` (the ones being
    /// spawned, which join at startup).
    fn notify_workers(&self, fresh: &[WorldDef], exclude: impl Fn(NodeId) -> bool) {
        let ctrl = self.worker_ctrl.lock().unwrap();
        for def in fresh {
            for &member in &def.members {
                if exclude(member) || member == NodeId::Leader {
                    continue;
                }
                if let Some(tx) = ctrl.get(&member) {
                    let _ = tx.send(TopoUpdate::AddWorld(def.clone()));
                }
            }
        }
    }

    /// Join the leader's side of any `fresh` world it belongs to. The
    /// call blocks until the world forms, so it must run *after* the
    /// replacement workers were spawned.
    fn join_leader(&self, fresh: &[WorldDef]) -> anyhow::Result<()> {
        for def in fresh {
            if def.members.contains(&NodeId::Leader) {
                (self.leader_join)(def)?;
            }
        }
        Ok(())
    }

    /// Drop removed world names from every strike set, so stale
    /// evidence from a cleaned-up incident can never help convict a
    /// live worker later.
    fn purge_strikes(&self, removed: &[String]) {
        if removed.is_empty() {
            return;
        }
        let mut strikes = self.strikes.lock().unwrap();
        for set in strikes.values_mut() {
            set.retain(|w| !removed.iter().any(|r| r == w));
        }
        strikes.retain(|_, s| !s.is_empty());
    }

    /// Retire a whole replica (scale-in): drain via Shutdown on every
    /// shard's control channel and drop the replica's worlds from the
    /// topology.
    pub fn scale_in(&self, node: NodeId) -> anyhow::Result<Option<Action>> {
        let NodeId::Worker { stage, replica, .. } = node else {
            return Ok(None);
        };
        let (removed, shards) = {
            let mut topo = self.topo.lock().unwrap();
            let shards = topo.shards_of(stage, replica);
            (topo.remove_replica(stage, replica), shards)
        };
        if removed.is_empty() {
            return Ok(None);
        }
        self.purge_strikes(&removed);
        let mut ctrl = self.worker_ctrl.lock().unwrap();
        for shard in shards {
            if let Some(tx) = ctrl.remove(&shard) {
                let _ = tx.send(TopoUpdate::Shutdown);
            }
        }
        drop(ctrl);
        crate::metrics::global().counter("controller.scale_ins").inc();
        crate::metrics::log_event(
            "controller.scaled_in",
            &[("node", node.to_string().as_str())],
        );
        let action = Action::ScaledIn { node };
        self.actions.lock().unwrap().push(action.clone());
        Ok(Some(action))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::topology::WorldKind;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Mutex};

    struct CountingSpawner {
        count: Arc<AtomicUsize>,
        nodes: Arc<Mutex<Vec<NodeId>>>,
    }

    impl Spawner for CountingSpawner {
        fn spawn(&self, node: NodeId, worlds: Vec<WorldDef>) -> anyhow::Result<()> {
            assert!(!worlds.is_empty());
            assert!(
                worlds.iter().any(|w| w.rank_of(node).is_some()),
                "spawned node must be a member of at least one fresh world"
            );
            self.count.fetch_add(1, Ordering::SeqCst);
            self.nodes.lock().unwrap().push(node);
            Ok(())
        }
    }

    type Spawned = (Controller, Arc<AtomicUsize>, Arc<Mutex<Vec<NodeId>>>);

    fn controller_for(topo: Topology, policy: ScalingPolicy) -> Spawned {
        let spawned = Arc::new(AtomicUsize::new(0));
        let nodes = Arc::new(Mutex::new(Vec::new()));
        let c = Controller::new(
            topo,
            policy,
            Box::new(CountingSpawner { count: spawned.clone(), nodes: nodes.clone() }),
            |_def| Ok(()),
        );
        (c, spawned, nodes)
    }

    fn controller(policy: ScalingPolicy) -> (Controller, Arc<AtomicUsize>) {
        let (c, spawned, _) =
            controller_for(Topology::pipeline("t", &[1, 2, 1], 31_000), policy);
        (c, spawned)
    }

    #[test]
    fn recovery_replaces_dead_worker_once() {
        let (c, spawned) = controller(ScalingPolicy::default());
        let p3 = NodeId::worker(1, 1);
        // When P3 dies, BOTH of its edge worlds break (Fig. 2b). The
        // first unattributed report only strikes; the second proves P3
        // dead (its neighbors still have healthy worlds elsewhere).
        let worlds: Vec<String> = c
            .topology()
            .worlds_of(p3)
            .iter()
            .map(|w| w.name.clone())
            .collect();
        assert_eq!(worlds.len(), 2);
        assert!(c.on_world_broken(&worlds[0], None).unwrap().is_none());
        let action = c.on_world_broken(&worlds[1], None).unwrap().unwrap();
        match action {
            Action::Recovered { dead, replacement } => {
                assert_eq!(dead, p3);
                assert_eq!(replacement, NodeId::worker(1, 2));
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(spawned.load(Ordering::SeqCst), 1);
        // Duplicate reports (the second edge also broke) are no-ops.
        assert!(c.declare_dead(p3).unwrap().is_none());
        assert_eq!(spawned.load(Ordering::SeqCst), 1);
        // Topology: P3 gone, replacement wired to both live neighbors.
        // (`replicas` is an id allocator: r1 is burned, r2 minted.)
        let topo = c.topology();
        assert_eq!(topo.replicas, vec![1, 3, 1]);
        assert_eq!(topo.live_replicas(1), vec![0, 2]);
        assert!(topo.worlds_of(p3).is_empty());
        let repl = NodeId::worker(1, 2);
        assert_eq!(topo.worlds_of(repl).len(), 2);
    }

    #[test]
    fn culprit_attribution_convicts_on_first_report() {
        let (c, spawned) = controller(ScalingPolicy::default());
        let p3 = NodeId::worker(1, 1);
        let world = c.topology().worlds_of(p3)[0].name.clone();
        let rank = c.topology().worlds_of(p3)[0].rank_of(p3).unwrap();
        let action = c.on_world_broken(&world, Some(rank)).unwrap().unwrap();
        assert!(matches!(action, Action::Recovered { dead, .. } if dead == p3));
        assert_eq!(spawned.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn leader_culprit_is_not_recovered() {
        let (c, spawned) = controller(ScalingPolicy::default());
        let topo = c.topology();
        let in_world = topo.in_edges(NodeId::worker(0, 0))[0].name.clone();
        // Rank 0 of an in-world is the leader.
        assert!(c.on_world_broken(&in_world, Some(0)).unwrap().is_none());
        assert_eq!(spawned.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn no_recovery_when_disabled() {
        let (c, spawned) =
            controller(ScalingPolicy { recover: false, ..Default::default() });
        let p2 = NodeId::worker(1, 0);
        assert!(c.declare_dead(p2).unwrap().is_none());
        assert_eq!(spawned.load(Ordering::SeqCst), 0);
        assert!(c.topology().worlds_of(p2).is_empty(), "corpse still removed");
    }

    #[test]
    fn scale_out_on_depth_threshold() {
        let (c, spawned) = controller(ScalingPolicy {
            scale_up_depth: 10.0,
            max_replicas: 3,
            recover: true,
        });
        assert!(c.maybe_scale_out(1, 5.0).unwrap().is_none(), "below threshold");
        let action = c.maybe_scale_out(1, 12.0).unwrap().unwrap();
        assert!(matches!(action, Action::ScaledOut { stage: 1, .. }));
        assert_eq!(spawned.load(Ordering::SeqCst), 1);
        assert_eq!(c.topology().replicas, vec![1, 3, 1]);
        // Ceiling respected.
        assert!(c.maybe_scale_out(1, 100.0).unwrap().is_none());
    }

    #[test]
    fn scale_in_retires_node() {
        let (c, _) = controller(ScalingPolicy::default());
        let node = NodeId::worker(1, 1);
        let (tx, rx) = std::sync::mpsc::channel();
        c.register_worker(node, tx);
        let action = c.scale_in(node).unwrap().unwrap();
        assert_eq!(action, Action::ScaledIn { node });
        assert!(matches!(rx.try_recv(), Ok(TopoUpdate::Shutdown)));
        assert!(c.topology().worlds_of(node).is_empty());
        // Second scale_in is a no-op.
        assert!(c.scale_in(node).unwrap().is_none());
    }

    #[test]
    fn existing_members_receive_add_world() {
        let (c, _) = controller(ScalingPolicy::default());
        let p1 = NodeId::worker(0, 0);
        let p4 = NodeId::worker(2, 0);
        let (tx1, rx1) = std::sync::mpsc::channel();
        let (tx4, rx4) = std::sync::mpsc::channel();
        c.register_worker(p1, tx1);
        c.register_worker(p4, tx4);
        c.maybe_scale_out(1, 1e9).unwrap().unwrap();
        // P1 gets the upstream edge, P4 the downstream edge.
        assert!(matches!(rx1.try_recv(), Ok(TopoUpdate::AddWorld(_))));
        assert!(matches!(rx4.try_recv(), Ok(TopoUpdate::AddWorld(_))));
    }

    // ------------------------------------------ sharded (tp > 1) cases

    fn tp_topology() -> Topology {
        // 2 stages; stage 1 has 2 replicas of 2 shards each.
        Topology::pipeline_tp("t", &[1, 2], &[1, 2], 35_000)
    }

    #[test]
    fn dead_nonhead_shard_is_respawned_under_its_own_id() {
        let (c, spawned, nodes) = controller_for(tp_topology(), ScalingPolicy::default());
        let shard1 = NodeId::Worker { stage: 1, replica: 0, shard: 1 };
        let head = NodeId::worker(1, 0);
        let (tx_head, rx_head) = std::sync::mpsc::channel();
        c.register_worker(head, tx_head);
        let tp_world = c.topology().tp_world_of(shard1).unwrap().name.clone();
        let old_edges: Vec<String> = c
            .topology()
            .worlds_of(head)
            .iter()
            .filter(|w| !w.is_tp())
            .map(|w| w.name.clone())
            .collect();

        // The watchdog attributes the TP-world break to rank 1 == shard 1.
        let action = c.on_world_broken(&tp_world, Some(1)).unwrap().unwrap();
        assert_eq!(
            action,
            Action::Recovered { dead: shard1, replacement: shard1 },
            "shard id survives; only its worlds are fresh"
        );
        assert_eq!(spawned.load(Ordering::SeqCst), 1, "only the dead shard respawns");
        assert_eq!(nodes.lock().unwrap().as_slice(), &[shard1]);
        // The surviving head rejoins the fresh TP world over control.
        match rx_head.try_recv() {
            Ok(TopoUpdate::AddWorld(def)) => {
                assert_eq!(def.kind, WorldKind::Tp);
                assert!(def.name.contains("#g1"), "fresh generation-tagged name: {}", def.name);
                assert_ne!(def.name, tp_world, "broken names are never reused");
            }
            other => panic!("{other:?}"),
        }
        // The head's healthy edges were not re-minted.
        let topo = c.topology();
        let new_edges: Vec<String> =
            topo.worlds_of(head).iter().filter(|w| !w.is_tp()).map(|w| w.name.clone()).collect();
        assert_eq!(old_edges, new_edges);
        // Duplicate reports of the old world are ignored…
        assert!(c.on_world_broken(&tp_world, Some(1)).unwrap().is_none());
        // …but the revived shard dying *again* is a new incident.
        let fresh_tp = topo.tp_world_of(shard1).unwrap().name.clone();
        let again = c.on_world_broken(&fresh_tp, Some(1)).unwrap().unwrap();
        assert!(matches!(again, Action::Recovered { dead, .. } if dead == shard1));
    }

    #[test]
    fn dead_head_shard_reminted_with_edges() {
        let (c, spawned, nodes) = controller_for(tp_topology(), ScalingPolicy::default());
        let head = NodeId::worker(1, 1);
        let shard1 = NodeId::Worker { stage: 1, replica: 1, shard: 1 };
        let (tx_s1, rx_s1) = std::sync::mpsc::channel();
        c.register_worker(shard1, tx_s1);
        let tp_world = c.topology().tp_world_of(head).unwrap().name.clone();
        let action = c.on_world_broken(&tp_world, Some(0)).unwrap().unwrap();
        assert_eq!(action, Action::Recovered { dead: head, replacement: head });
        assert_eq!(spawned.load(Ordering::SeqCst), 1);
        assert_eq!(nodes.lock().unwrap().as_slice(), &[head]);
        // The surviving shard rejoins the fresh TP world; the head's
        // fresh edges went to its neighbors (here: the leader callback
        // and the upstream head, not registered — no panic).
        assert!(matches!(rx_s1.try_recv(), Ok(TopoUpdate::AddWorld(_))));
        let topo = c.topology();
        assert!(topo.tp_world_of(head).unwrap().name.contains("#g1"));
        assert_eq!(topo.in_edges(head).len(), 1);
        assert!(topo.in_edges(head)[0].name.contains("#g1"));
    }

    #[test]
    fn tp_neighbors_are_never_convicted_by_strikes() {
        // Unattributed TP-world evidence alone must not convict the
        // non-head shard (its only world broke, but when a head dies its
        // shards' TP world breaks too — only attribution can tell).
        let (c, spawned, _) = controller_for(tp_topology(), ScalingPolicy::default());
        let shard1 = NodeId::Worker { stage: 1, replica: 0, shard: 1 };
        let tp_world = c.topology().tp_world_of(shard1).unwrap().name.clone();
        assert!(c.on_world_broken(&tp_world, None).unwrap().is_none());
        assert_eq!(spawned.load(Ordering::SeqCst), 0);
        // Edge evidence then convicts the head, not the shard.
        let head = NodeId::worker(1, 0);
        let edges: Vec<String> = c
            .topology()
            .worlds_of(head)
            .iter()
            .filter(|w| !w.is_tp())
            .map(|w| w.name.clone())
            .collect();
        let mut last = None;
        for e in &edges {
            last = c.on_world_broken(e, None).unwrap();
        }
        let action = last.expect("head convicted once all its worlds are broken");
        assert!(matches!(action, Action::Recovered { dead, .. } if dead == head));
    }

    #[test]
    fn scale_out_of_sharded_stage_spawns_all_shards() {
        let (c, spawned, nodes) = controller_for(
            tp_topology(),
            ScalingPolicy { scale_up_depth: 1.0, max_replicas: 3, recover: true },
        );
        let action = c.maybe_scale_out(1, 100.0).unwrap().unwrap();
        assert!(matches!(action, Action::ScaledOut { stage: 1, .. }));
        assert_eq!(spawned.load(Ordering::SeqCst), 2, "both shards spawned");
        let got = nodes.lock().unwrap().clone();
        assert_eq!(
            got,
            vec![
                NodeId::Worker { stage: 1, replica: 2, shard: 0 },
                NodeId::Worker { stage: 1, replica: 2, shard: 1 },
            ]
        );
        let topo = c.topology();
        assert!(topo.tp_world_of(NodeId::worker(1, 2)).is_some());
    }

    #[test]
    fn scale_in_retires_every_shard_of_the_replica() {
        let (c, _, _) = controller_for(tp_topology(), ScalingPolicy::default());
        let head = NodeId::worker(1, 0);
        let shard1 = NodeId::Worker { stage: 1, replica: 0, shard: 1 };
        let (tx0, rx0) = std::sync::mpsc::channel();
        let (tx1, rx1) = std::sync::mpsc::channel();
        c.register_worker(head, tx0);
        c.register_worker(shard1, tx1);
        let action = c.scale_in(head).unwrap().unwrap();
        assert_eq!(action, Action::ScaledIn { node: head });
        assert!(matches!(rx0.try_recv(), Ok(TopoUpdate::Shutdown)));
        assert!(matches!(rx1.try_recv(), Ok(TopoUpdate::Shutdown)));
        assert!(c.topology().worlds_of(head).is_empty());
        assert!(c.topology().worlds_of(shard1).is_empty());
    }
}
