//! The leader: the always-on serving runtime. Request ingress with
//! SLO-aware admission, dynamic batching, dispatch into the pipeline,
//! response collection, retry on loss, and latency accounting.
//!
//! ## Runtime architecture
//!
//! The leader runs two persistent threads (started lazily by
//! [`Leader::start_runtime`], or by the first `submit`/`serve` call):
//!
//! * **dispatcher** — loops on the admission queue's `next_batch`,
//!   packs each batch and routes it to a live stage-0 replica
//!   (least-inflight). Expired requests never reach it: the
//!   [`DynamicBatcher`] drops them at the queue head.
//! * **collector** — posts irecvs on every `out-*` edge, harvests
//!   responses, resolves request handles, reacts to broken-world
//!   events, and sweeps outstanding batches (redispatch after
//!   `retry_timeout`, give up after `retry_max_attempts`).
//!
//! Clients call [`Leader::submit`] (load-shedding admission) or
//! [`Leader::submit_blocking`] (backpressure admission) and hold a
//! [`RequestHandle`] that resolves to exactly one
//! [`Outcome`](crate::serving::request::Outcome): a response, an SLO
//! drop, or an admission rejection. The run-to-completion
//! [`Leader::serve`] from earlier revisions survives as a thin
//! compatibility wrapper: submit-all, wait-all, report.
//!
//! ## The decode loop (continuous batching)
//!
//! Multi-token requests (`max_tokens > 1`) bypass the dispatcher
//! entirely: they queue on a second [`DynamicBatcher`] drained by the
//! **decode scheduler**, which keeps one [`Lane`](super::decode::Lane)
//! per stage-0 in-edge holding a slot-addressed running batch. Every
//! iteration it admits queued requests into free slots (prefill),
//! retires finished or SLO-expired ones, and sends one
//! [`StepFrame`](super::decode::StepFrame) per lane (at most one in
//! flight per lane); the collector recognises returning step frames by
//! their magic, harvests **one token per occupied slot**, pushes each
//! down its request's token stream, and immediately schedules the next
//! iteration — so iteration latency is round-trip-bound, with a
//! low-frequency scheduler thread covering pacing, retries (identical
//! frame resend — worker directive application is idempotent), SLO
//! eviction (TTFT before the first token, inter-token gap after) and
//! lane reconciliation. Generated tokens are leader-side state: when a
//! lane dies mid-decode its residents requeue and **re-prefill**
//! (prompt + generated so far) on a surviving lane — recomputation,
//! never a lost request. With `max_tokens = 1` none of this machinery
//! is ever touched and the one-shot path is byte-identical to the
//! pre-streaming runtime.
//!
//! The leader is rank 0 of each `in-*` world (feeding stage-0 replicas)
//! and rank 1 of each `out-*` world (hearing from last-stage replicas).
//! Batches carry an id in their [`Envelope`]; responses are correlated
//! by id, so replicated stages may reorder freely. Lost batches (a
//! worker died while holding them) are re-dispatched after
//! `retry_timeout` — at-least-once with response dedupe.

use super::batcher::{DynamicBatcher, TenantClass};
use super::decode::{
    pack_step_rows, token_hash, ActiveReq, DecodeState, Inflight, StepEntry, StepFrame,
    StepPhase,
};
use super::request::{
    DropReason, Outcome, OutcomeSlot, RejectReason, Request, RequestHandle, Response,
    TenantId, TokenStream,
};
use super::router::ReplicaRouter;
use super::stage_worker::{Envelope, TAG_DATA};
use super::topology::{NodeId, Topology, WorldDef};
use crate::metrics::{Counter, Histogram, SlidingWindow, Timeline};
use crate::multiworld::{WorldCommunicator, WorldEvent, WorldManager};
use crate::mwccl::{Work, WorldOptions};
use crate::tensor::{DType, Tensor};
use crate::util::time::since_epoch;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Cap on the cumulative response-introspection buffer (the runtime is
/// always-on; clients receive responses through their handles).
const RESPONSES_KEEP: usize = 65_536;

struct Outstanding {
    requests: Vec<Request>,
    sent_at: Instant,
    attempts: u32,
}

struct RuntimeThreads {
    dispatcher: std::thread::JoinHandle<()>,
    collector: std::thread::JoinHandle<()>,
}

/// Resolved per-tenant serving state: effective SLO class (the tenant's
/// own values, inheriting the global knobs where a field is 0), a
/// recent-latency window for per-tenant p99 / breach attribution, and
/// pre-resolved labelled metrics. Built only when `MW_TENANTS` names a
/// tenant table — the single-tenant runtime carries none of this.
struct TenantState {
    slo: Option<Duration>,
    slo_ttft: Option<Duration>,
    slo_itl: Option<Duration>,
    /// Whole-request SLO in ms (0 = none) — the attribution threshold
    /// the autoscaler compares per-tenant p99 against.
    slo_ms: f64,
    /// Recent per-tenant latency window (p99 signal).
    recent: SlidingWindow,
    completed: Arc<Counter>,
    dropped_deadline: Arc<Counter>,
    rejected_queue_full: Arc<Counter>,
    /// Registry window name for per-tenant TTFT observations.
    ttft_metric: String,
}

impl TenantState {
    fn resolve(name: &str, slo_ms: u64, ttft_ms: u64, itl_ms: u64, window: Duration) -> Self {
        let g = crate::metrics::global();
        TenantState {
            slo: (slo_ms > 0).then(|| Duration::from_millis(slo_ms)),
            slo_ttft: (ttft_ms > 0).then(|| Duration::from_millis(ttft_ms)),
            slo_itl: (itl_ms > 0).then(|| Duration::from_millis(itl_ms)),
            slo_ms: slo_ms as f64,
            recent: SlidingWindow::new(window),
            completed: g.counter(&format!("serving.completed.tenant.{name}")),
            dropped_deadline: g.counter(&format!("serving.dropped.deadline.tenant.{name}")),
            rejected_queue_full: g
                .counter(&format!("serving.rejected.queue_full.tenant.{name}")),
            ttft_metric: format!("serving.ttft_ms.tenant.{name}"),
        }
    }
}

/// How long the decode scheduler thread sleeps between passes. The
/// collector drives the hot path (next frame as soon as the previous
/// one returns); this cadence only bounds retry/eviction latency.
const DECODE_TICK: Duration = Duration::from_micros(500);

/// See module docs.
pub struct Leader {
    mgr: WorldManager,
    comm: WorldCommunicator,
    pub batcher: Arc<DynamicBatcher>,
    in_router: ReplicaRouter,
    out_edges: Mutex<Vec<String>>,
    batch_size: usize,
    seq_len: usize,
    vocab: usize,
    /// Per-request SLO budget stamped at admission (None = no SLO).
    slo: Option<Duration>,
    next_batch_id: AtomicU64,
    outstanding: Mutex<HashMap<u64, Outstanding>>,
    /// Unresolved request handles by request id.
    handles: Mutex<HashMap<u64, Arc<OutcomeSlot>>>,
    /// Most recent responses, bounded at `RESPONSES_KEEP`
    /// (introspection only — clients get theirs through the handle).
    responses: Mutex<VecDeque<Response>>,
    /// Cumulative latency distribution (reports).
    pub latency: Histogram,
    /// Recent-latency window (the autoscaler's SLO signal).
    recent: SlidingWindow,
    pub timeline: Timeline,
    retry_timeout: Duration,
    retry_max_attempts: u32,
    retries: AtomicU64,
    runtime: Mutex<Option<RuntimeThreads>>,
    stop: Arc<AtomicBool>,
    /// Streaming admission queue (multi-token requests). Separate from
    /// `batcher` so the legacy dispatcher never steals a streaming
    /// request and the one-shot path stays byte-identical.
    pub stream_batcher: Arc<DynamicBatcher>,
    /// Decode-loop scheduler state: lanes keyed by in-edge plus the
    /// re-prefill queue.
    decode: Mutex<DecodeState>,
    /// Token streams of in-flight streaming requests, by request id.
    streams: Mutex<HashMap<u64, Arc<TokenStream>>>,
    decode_on: AtomicBool,
    decode_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Deployment-default decode budget (`MW_MAX_TOKENS`); a request's
    /// own `max_tokens > 1` overrides it.
    default_max_tokens: u32,
    /// Time-to-first-token SLO (`MW_SLO_TTFT_MS`).
    slo_ttft: Option<Duration>,
    /// Inter-token-gap SLO (`MW_SLO_ITL_MS`).
    slo_itl: Option<Duration>,
    /// Gang-schedule ablation (`MW_DECODE_GANG`): step framing, but
    /// admission only into an empty batch.
    decode_gang: bool,
    /// Recent TTFT window (autoscaler signal).
    ttft_recent: SlidingWindow,
    /// Recent decoded-token events (tokens/s signal: count / window).
    token_events: SlidingWindow,
    token_window: Duration,
    /// Per-tenant SLO classes and labelled metrics (`MW_TENANTS`);
    /// empty = single-tenant runtime, byte-identical to the
    /// pre-tenancy behavior.
    tenants: BTreeMap<TenantId, TenantState>,
    /// Cached key for the implicit default class unknown tenants fold
    /// into.
    default_tenant: TenantId,
}

/// Final numbers for a serve run.
#[derive(Clone, Debug)]
pub struct LeaderReport {
    pub completed: usize,
    /// Admission rejections (queue full / malformed / duplicate id).
    pub rejected: usize,
    /// Admitted but never answered (SLO expiry, retry exhaustion,
    /// shutdown, or the run deadline passing first).
    pub dropped: usize,
    pub duration: f64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub retries: u64,
}

impl Leader {
    /// Join the leader's worlds and set up the data structures.
    /// `batch_size`/`seq_len`/`vocab` come from the model manifest.
    pub fn new(
        mgr: WorldManager,
        topo: &Topology,
        opts: &WorldOptions,
        batch_size: usize,
        seq_len: usize,
        vocab: usize,
        cfg: &crate::config::ServingConfig,
    ) -> anyhow::Result<Arc<Leader>> {
        super::stage_worker::init_node_worlds(&mgr, topo, NodeId::Leader, opts)?;
        let comm = mgr.communicator();
        let in_router = ReplicaRouter::new(cfg.replica_inflight);
        for w in topo.out_edges(NodeId::Leader) {
            in_router.add_replica(&w.name);
        }
        let out_edges: Vec<String> = topo
            .in_edges(NodeId::Leader)
            .iter()
            .map(|w| w.name.clone())
            .collect();
        // Tenant table: admission classes for the batchers plus the
        // leader-side SLO/metric state. Empty with `MW_TENANTS` unset —
        // everything below then reduces to the single-tenant runtime.
        let classes: Vec<TenantClass> = cfg
            .tenants
            .iter()
            .map(|t| TenantClass::new(&t.name, t.weight, t.depth))
            .collect();
        let window = Duration::from_millis(cfg.scale_window_ms.max(1));
        let default_tenant = TenantId::default();
        let mut tenants: BTreeMap<TenantId, TenantState> = BTreeMap::new();
        if !cfg.tenants.is_empty() {
            for spec in &cfg.tenants {
                // A 0 field inherits the matching global knob.
                let slo = if spec.slo_ms > 0 { spec.slo_ms } else { cfg.slo_ms };
                let ttft = if spec.slo_ttft_ms > 0 { spec.slo_ttft_ms } else { cfg.slo_ttft_ms };
                let itl = if spec.slo_itl_ms > 0 { spec.slo_itl_ms } else { cfg.slo_itl_ms };
                tenants.insert(
                    TenantId::new(&spec.name),
                    TenantState::resolve(&spec.name, slo, ttft, itl, window),
                );
            }
            tenants.entry(default_tenant.clone()).or_insert_with(|| {
                TenantState::resolve(
                    super::request::DEFAULT_TENANT,
                    cfg.slo_ms,
                    cfg.slo_ttft_ms,
                    cfg.slo_itl_ms,
                    window,
                )
            });
        }
        let leader = Arc::new(Leader {
            mgr,
            comm,
            batcher: DynamicBatcher::with_tenants(
                batch_size,
                Duration::from_millis(cfg.batch_timeout_ms),
                cfg.admission_depth,
                &classes,
            ),
            in_router,
            out_edges: Mutex::new(out_edges),
            batch_size,
            seq_len,
            vocab,
            slo: (cfg.slo_ms > 0).then(|| Duration::from_millis(cfg.slo_ms)),
            next_batch_id: AtomicU64::new(1),
            outstanding: Mutex::new(HashMap::new()),
            handles: Mutex::new(HashMap::new()),
            responses: Mutex::new(VecDeque::new()),
            latency: Histogram::default(),
            recent: SlidingWindow::new(Duration::from_millis(cfg.scale_window_ms.max(1))),
            timeline: Timeline::new(),
            retry_timeout: Duration::from_millis(cfg.retry_timeout_ms),
            retry_max_attempts: cfg.retry_max_attempts,
            retries: AtomicU64::new(0),
            runtime: Mutex::new(None),
            stop: Arc::new(AtomicBool::new(false)),
            stream_batcher: DynamicBatcher::with_tenants(
                batch_size,
                Duration::from_millis(cfg.batch_timeout_ms),
                cfg.admission_depth,
                &classes,
            ),
            decode: Mutex::new(DecodeState::new(batch_size)),
            streams: Mutex::new(HashMap::new()),
            decode_on: AtomicBool::new(false),
            decode_thread: Mutex::new(None),
            default_max_tokens: cfg.max_tokens.max(1),
            slo_ttft: (cfg.slo_ttft_ms > 0).then(|| Duration::from_millis(cfg.slo_ttft_ms)),
            slo_itl: (cfg.slo_itl_ms > 0).then(|| Duration::from_millis(cfg.slo_itl_ms)),
            decode_gang: cfg.decode_gang,
            ttft_recent: SlidingWindow::new(Duration::from_millis(cfg.scale_window_ms.max(1))),
            token_events: SlidingWindow::new(Duration::from_millis(cfg.scale_window_ms.max(1))),
            token_window: Duration::from_millis(cfg.scale_window_ms.max(1)),
            tenants,
            default_tenant,
        });
        // The admission queues resolve the handle of every request they
        // drop instead of dispatching — SLO / TTFT deadline expiry, or
        // a legacy push into a closed queue (Shutdown); resolve also
        // finishes a streaming request's token stream.
        let weak = Arc::downgrade(&leader);
        leader.batcher.set_drop_hook(Box::new(move |r: Request, why: DropReason| {
            if let Some(me) = weak.upgrade() {
                me.note_queue_drop(&r.tenant, why);
                me.resolve(r.id, Outcome::Dropped(why));
            }
        }));
        let weak = Arc::downgrade(&leader);
        leader.stream_batcher.set_drop_hook(Box::new(move |r: Request, why: DropReason| {
            if let Some(me) = weak.upgrade() {
                me.note_queue_drop(&r.tenant, why);
                me.resolve(r.id, Outcome::Dropped(why));
            }
        }));
        Ok(leader)
    }

    /// The manager (for event wiring by the controller).
    pub fn manager(&self) -> &WorldManager {
        &self.mgr
    }

    /// Join a fresh world created by online instantiation (the leader's
    /// side; called by the controller).
    pub fn join_world(&self, def: &WorldDef, opts: &WorldOptions) -> anyhow::Result<()> {
        let rank = def
            .rank_of(NodeId::Leader)
            .ok_or_else(|| anyhow::anyhow!("leader not in {}", def.name))?;
        let addr: std::net::SocketAddr =
            format!("127.0.0.1:{}", def.store_port).parse().unwrap();
        self.mgr
            .initialize_world(&def.name, rank, def.size(), addr, opts.clone())?;
        if rank == 0 {
            self.in_router.add_replica(&def.name);
        } else {
            self.out_edges.lock().unwrap().push(def.name.clone());
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Ingress: admission + the client-facing submit API.
    // ------------------------------------------------------------------

    /// Submit one request to the always-on runtime with load-shedding
    /// admission: a full bounded queue rejects instead of blocking.
    /// Starts the runtime threads on first use.
    pub fn submit(self: &Arc<Self>, r: Request) -> RequestHandle {
        self.start_runtime();
        self.admit(r, false)
    }

    /// Submit with backpressure admission: blocks for queue space
    /// instead of shedding (closed-loop callers).
    pub fn submit_blocking(self: &Arc<Self>, r: Request) -> RequestHandle {
        self.start_runtime();
        self.admit(r, true)
    }

    fn admit(self: &Arc<Self>, mut r: Request, block: bool) -> RequestHandle {
        let g = crate::metrics::global();
        if r.tokens.len() != self.seq_len {
            // Malformed requests die at admission — never inside the
            // dispatcher (where they used to panic the thread).
            g.counter("serving.rejected.malformed").inc();
            return RequestHandle::resolved(
                r.id,
                Outcome::Rejected(RejectReason::Malformed {
                    got: r.tokens.len(),
                    want: self.seq_len,
                }),
            );
        }
        r.arrival = since_epoch();
        // Effective decode budget: the request's own `max_tokens` wins,
        // otherwise the deployment default. Budget 1 is the legacy
        // one-shot path, byte-identical to the pre-streaming runtime.
        let budget = if r.max_tokens > 1 { r.max_tokens } else { self.default_max_tokens };
        if budget > 1 {
            return self.admit_streaming(r, budget, block);
        }
        let (slo, _, _) = self.slos_for(&r.tenant);
        r.deadline = slo.map(|slo| r.arrival + slo.as_secs_f64());
        let tenant = r.tenant.clone();
        let id = r.id;
        let slot = Arc::new(OutcomeSlot::default());
        {
            let mut handles = self.handles.lock().unwrap();
            match handles.entry(id) {
                std::collections::hash_map::Entry::Occupied(_) => {
                    drop(handles);
                    g.counter("serving.rejected.duplicate").inc();
                    return RequestHandle::resolved(
                        id,
                        Outcome::Rejected(RejectReason::DuplicateId),
                    );
                }
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert(slot.clone());
                }
            }
        }
        let pushed = if block {
            self.batcher.push_wait(r)
        } else {
            self.batcher.try_push(r)
        };
        match pushed {
            Ok(_) => {
                g.counter("serving.admitted").inc();
                RequestHandle::new(id, slot)
            }
            Err(_) => {
                self.handles.lock().unwrap().remove(&id);
                let outcome = if self.stop.load(Ordering::Relaxed) {
                    Outcome::Dropped(DropReason::Shutdown)
                } else {
                    g.counter("serving.rejected.queue_full").inc();
                    if let Some(ts) = self.tenant_state(&tenant) {
                        ts.rejected_queue_full.inc();
                    }
                    Outcome::Rejected(RejectReason::QueueFull)
                };
                RequestHandle::resolved(id, outcome)
            }
        }
    }

    /// Streaming admission: multi-token requests get a token stream and
    /// queue on the decode scheduler's own batcher — the legacy
    /// dispatcher never sees them.
    fn admit_streaming(self: &Arc<Self>, mut r: Request, budget: u32, block: bool) -> RequestHandle {
        let g = crate::metrics::global();
        r.max_tokens = budget;
        // Queue deadline: until the first token the tenant's TTFT SLO
        // is the deadline; without one, fall back to its whole-request
        // SLO.
        let (slo, slo_ttft, _) = self.slos_for(&r.tenant);
        let queue_slo = slo_ttft.or(slo);
        r.deadline = queue_slo.map(|slo| r.arrival + slo.as_secs_f64());
        let tenant = r.tenant.clone();
        let id = r.id;
        let slot = Arc::new(OutcomeSlot::default());
        {
            let mut handles = self.handles.lock().unwrap();
            match handles.entry(id) {
                std::collections::hash_map::Entry::Occupied(_) => {
                    drop(handles);
                    g.counter("serving.rejected.duplicate").inc();
                    return RequestHandle::resolved(
                        id,
                        Outcome::Rejected(RejectReason::DuplicateId),
                    );
                }
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert(slot.clone());
                }
            }
        }
        let stream = Arc::new(TokenStream::default());
        self.streams.lock().unwrap().insert(id, stream.clone());
        let pushed = if block {
            self.stream_batcher.push_wait(r)
        } else {
            self.stream_batcher.try_push(r)
        };
        match pushed {
            Ok(_) => {
                g.counter("serving.admitted").inc();
                g.counter("serving.admitted.streaming").inc();
                self.ensure_decode_runtime();
                RequestHandle::new_streaming(id, slot, stream)
            }
            Err(_) => {
                self.handles.lock().unwrap().remove(&id);
                self.streams.lock().unwrap().remove(&id);
                let outcome = if self.stop.load(Ordering::Relaxed) {
                    Outcome::Dropped(DropReason::Shutdown)
                } else {
                    g.counter("serving.rejected.queue_full").inc();
                    if let Some(ts) = self.tenant_state(&tenant) {
                        ts.rejected_queue_full.inc();
                    }
                    Outcome::Rejected(RejectReason::QueueFull)
                };
                RequestHandle::resolved(id, outcome)
            }
        }
    }

    /// Per-tenant state for a request's tenant: exact match, else the
    /// implicit default class (unknown tenants fold there, mirroring
    /// the batcher); `None` on a single-tenant runtime.
    fn tenant_state(&self, t: &TenantId) -> Option<&TenantState> {
        if self.tenants.is_empty() {
            return None;
        }
        self.tenants.get(t).or_else(|| self.tenants.get(&self.default_tenant))
    }

    /// Effective (request, TTFT, inter-token) SLOs for a tenant — its
    /// class when configured, the global knobs otherwise.
    fn slos_for(&self, t: &TenantId) -> (Option<Duration>, Option<Duration>, Option<Duration>) {
        match self.tenant_state(t) {
            Some(ts) => (ts.slo, ts.slo_ttft, ts.slo_itl),
            None => (self.slo, self.slo_ttft, self.slo_itl),
        }
    }

    /// Account one admission-queue drop (global + per-tenant counters).
    /// Deadline expiries feed the SLO drop counters; Shutdown drops
    /// (push into a closed queue) resolve the handle without them.
    fn note_queue_drop(&self, tenant: &TenantId, why: DropReason) {
        if why == DropReason::Deadline {
            crate::metrics::global().counter("serving.dropped.deadline").inc();
            if let Some(ts) = self.tenant_state(tenant) {
                ts.dropped_deadline.inc();
            }
        }
    }

    /// Resolve a request's handle (first outcome wins; later calls for
    /// the same id are no-ops). A streaming request's token stream is
    /// finished with the same outcome, after any already-pushed tokens.
    fn resolve(&self, id: u64, outcome: Outcome) {
        let slot = self.handles.lock().unwrap().remove(&id);
        if let Some(stream) = self.streams.lock().unwrap().remove(&id) {
            stream.finish(outcome.clone());
        }
        if let Some(slot) = slot {
            slot.resolve(outcome);
        }
    }

    // ------------------------------------------------------------------
    // Runtime threads.
    // ------------------------------------------------------------------

    /// Start the persistent dispatcher + collector threads (idempotent).
    /// The threads hold only `Weak` references, so dropping the last
    /// external `Arc<Leader>` shuts them down.
    pub fn start_runtime(self: &Arc<Self>) {
        let mut rt = self.runtime.lock().unwrap();
        if rt.is_some() {
            return;
        }
        let batcher = self.batcher.clone();
        let weak = Arc::downgrade(self);
        let dispatcher = std::thread::Builder::new()
            .name("leader-dispatch".into())
            .spawn(move || {
                while let Some(batch) = batcher.next_batch() {
                    let Some(me) = weak.upgrade() else { break };
                    if me.stop.load(Ordering::Relaxed) {
                        for r in batch {
                            me.resolve(r.id, Outcome::Dropped(DropReason::Shutdown));
                        }
                        continue;
                    }
                    me.dispatch_batch(batch);
                }
            })
            .expect("spawn leader dispatcher");
        let weak = Arc::downgrade(self);
        let events = self.mgr.subscribe();
        let collector = std::thread::Builder::new()
            .name("leader-collect".into())
            .spawn(move || {
                let mut pending: HashMap<String, Work> = HashMap::new();
                loop {
                    let Some(me) = weak.upgrade() else { break };
                    if me.stop.load(Ordering::Relaxed) {
                        break;
                    }
                    me.collect_tick(&events, &mut pending);
                }
            })
            .expect("spawn leader collector");
        *rt = Some(RuntimeThreads { dispatcher, collector });
    }

    /// Stop the runtime: close admission, join the threads, resolve
    /// everything still in flight as shutdown-dropped. Terminal — the
    /// leader cannot serve afterwards.
    pub fn stop_runtime(&self) {
        self.stop.store(true, Ordering::Relaxed);
        self.batcher.close();
        self.stream_batcher.close();
        let rt = self.runtime.lock().unwrap().take();
        if let Some(rt) = rt {
            let _ = rt.dispatcher.join();
            let _ = rt.collector.join();
        }
        if let Some(t) = self.decode_thread.lock().unwrap().take() {
            let _ = t.join();
        }
        let unresolved: Vec<u64> = self.handles.lock().unwrap().keys().copied().collect();
        for id in unresolved {
            // Also finishes streaming requests' token streams.
            self.resolve(id, Outcome::Dropped(DropReason::Shutdown));
        }
        self.outstanding.lock().unwrap().clear();
        let mut st = self.decode.lock().unwrap();
        st.lanes.clear();
        st.requeue.clear();
    }

    // ------------------------------------------------------------------
    // The decode loop (continuous batching).
    // ------------------------------------------------------------------

    /// Start the decode scheduler thread (idempotent; lazily started by
    /// the first streaming admission). The collector drives the hot
    /// path — this thread covers pacing, retries, SLO eviction and lane
    /// reconciliation when no frames are returning.
    fn ensure_decode_runtime(self: &Arc<Self>) {
        if self.decode_on.swap(true, Ordering::SeqCst) {
            return;
        }
        let weak = Arc::downgrade(self);
        let t = std::thread::Builder::new()
            .name("leader-decode".into())
            .spawn(move || loop {
                let Some(me) = weak.upgrade() else { break };
                if me.stop.load(Ordering::Relaxed) {
                    break;
                }
                me.decode_tick();
                drop(me);
                std::thread::sleep(DECODE_TICK);
            })
            .expect("spawn leader decode");
        *self.decode_thread.lock().unwrap() = Some(t);
    }

    /// One decode-scheduler pass: reconcile lanes with router liveness,
    /// retry or fail stale frames, evict SLO violators, admit queued
    /// requests into free slots, and send one step frame per idle lane
    /// with work. Safe to call from multiple threads (the collector
    /// calls it after every harvested frame): the per-lane `inflight`
    /// marker, set under the state lock before any send, makes frame
    /// emission single-shot.
    fn decode_tick(&self) {
        let now = since_epoch();
        let alive = self.in_router.alive_replicas();
        let g = crate::metrics::global();
        let mut to_send: Vec<(String, Tensor)> = Vec::new();
        let mut evicted: Vec<(u64, TenantId)> = Vec::new();
        let mut dead_lanes: Vec<String> = Vec::new();
        {
            let mut guard = self.decode.lock().unwrap();
            let st = &mut *guard;
            st.sync_lanes(&alive);
            for lane in st.lanes.values_mut() {
                // At most one frame in flight per lane.
                if let Some(inf) = &mut lane.inflight {
                    if inf.sent_at.elapsed() > self.retry_timeout {
                        if inf.attempts >= self.retry_max_attempts {
                            dead_lanes.push(lane.edge.clone());
                        } else {
                            inf.attempts += 1;
                            inf.sent_at = Instant::now();
                            self.retries.fetch_add(1, Ordering::Relaxed);
                            self.timeline.record_labeled(
                                "retry",
                                1.0,
                                &format!("step {}", inf.iter),
                            );
                            to_send.push((lane.edge.clone(), inf.env.clone()));
                        }
                    }
                    continue;
                }
                // SLO eviction: the occupant tenant's TTFT SLO until
                // the first token, its inter-token gap SLO afterwards.
                for (s, slot) in lane.slots.iter_mut().enumerate() {
                    let Some(a) = slot else { continue };
                    let (_, slo_ttft, slo_itl) = self.slos_for(&a.req.tenant);
                    let over = match a.first_token_at {
                        None => {
                            slo_ttft.is_some_and(|d| now > a.req.arrival + d.as_secs_f64())
                        }
                        Some(_) => {
                            slo_itl.is_some_and(|d| now > a.last_token_at + d.as_secs_f64())
                        }
                    };
                    if over {
                        lane.retiring.push((s as u16, a.req.id));
                        evicted.push((a.req.id, a.req.tenant.clone()));
                        *slot = None;
                    }
                }
                // Admission into free slots — continuous by default;
                // gang mode (the ablation baseline) only refills an
                // empty batch. Requeued (re-prefill) requests go ahead
                // of fresh arrivals.
                let free: Vec<usize> = lane
                    .slots
                    .iter()
                    .enumerate()
                    .filter_map(|(i, s)| s.is_none().then_some(i))
                    .collect();
                let admit_n = if self.decode_gang && free.len() < lane.slots.len() {
                    0
                } else {
                    free.len()
                };
                let mut incoming: Vec<ActiveReq> = Vec::new();
                while incoming.len() < admit_n {
                    let Some(a) = st.requeue.pop_front() else { break };
                    incoming.push(a);
                }
                if incoming.len() < admit_n {
                    incoming.extend(
                        self.stream_batcher
                            .take_ready(admit_n - incoming.len())
                            .into_iter()
                            .map(ActiveReq::new),
                    );
                }
                for (slot_idx, a) in free.into_iter().zip(incoming) {
                    lane.slots[slot_idx] = Some(a);
                }
                // Cut the frame: staged retirements plus one directive
                // per occupant.
                let mut entries: Vec<StepEntry> = lane
                    .retiring
                    .drain(..)
                    .map(|(slot, req_id)| StepEntry {
                        slot,
                        req_id,
                        pos: 0,
                        budget: 0,
                        phase: StepPhase::Retire,
                    })
                    .collect();
                for (s, slot) in lane.slots.iter().enumerate() {
                    if let Some(a) = slot {
                        entries.push(StepEntry {
                            slot: s as u16,
                            req_id: a.req.id,
                            pos: a.generated.len() as u32,
                            budget: a.remaining(),
                            phase: if a.prefilled {
                                StepPhase::Decode
                            } else {
                                StepPhase::Prefill
                            },
                        });
                    }
                }
                if entries.is_empty() {
                    continue; // idle lane
                }
                let payload = pack_step_rows(&lane.slots, self.batch_size, self.seq_len);
                let iter = self.next_batch_id.fetch_add(1, Ordering::Relaxed);
                let env = Envelope { id: iter, tensor: StepFrame { entries, payload }.pack() }
                    .pack();
                lane.inflight = Some(Inflight {
                    iter,
                    sent_at: Instant::now(),
                    attempts: 1,
                    env: env.clone(),
                });
                to_send.push((lane.edge.clone(), env));
            }
            for edge in &dead_lanes {
                g.counter("serving.decode.lane_failed").inc();
                self.in_router.mark_dead(edge);
                st.kill_lane(edge);
            }
        }
        if !evicted.is_empty() {
            g.counter("serving.dropped.deadline").add(evicted.len() as u64);
            for (id, tenant) in evicted {
                if let Some(ts) = self.tenant_state(&tenant) {
                    ts.dropped_deadline.inc();
                }
                self.resolve(id, Outcome::Dropped(DropReason::Deadline));
            }
        }
        for (edge, env) in to_send {
            if self.comm.send_blocking(&edge, env, 1, TAG_DATA).is_err() {
                // Dead edge: the next pass kills the lane and requeues
                // its residents for re-prefill.
                self.in_router.mark_dead(&edge);
            }
        }
    }

    /// Harvest one returned step frame: one token per occupied slot,
    /// pushed down the request's stream; exhausted requests finish with
    /// a [`Response`] and their slots are staged for retirement on the
    /// next frame.
    fn harvest_step(&self, env: Envelope) {
        let g = crate::metrics::global();
        let Ok(frame) = StepFrame::unpack(&env.tensor) else {
            g.counter("serving.step.corrupt").inc();
            return;
        };
        // Forward-only pipelines echo the (i32) step payload instead of
        // producing logits; stream deterministic hash tokens so the
        // decode lifecycle is still fully observable.
        let decodable = frame.payload.dtype() == DType::F32
            && frame.payload.elems() >= self.batch_size * self.seq_len * self.vocab;
        let now = since_epoch();
        let mut tokens_out: Vec<(u64, i32)> = Vec::new();
        let mut finished: Vec<(Response, TenantId)> = Vec::new();
        {
            let mut guard = self.decode.lock().unwrap();
            let st = &mut *guard;
            let Some(lane) = st
                .lanes
                .values_mut()
                .find(|l| l.inflight.as_ref().is_some_and(|i| i.iter == env.id))
            else {
                return; // stale frame: lane died, or a retry's duplicate
            };
            lane.inflight = None;
            for e in &frame.entries {
                if e.phase == StepPhase::Retire {
                    continue;
                }
                let Some(slot) = lane.slots.get_mut(e.slot as usize) else { continue };
                let Some(a) = slot.as_mut() else { continue };
                if a.req.id != e.req_id {
                    continue; // slot reassigned after this frame was cut
                }
                let tok = if decodable {
                    argmax_last(&frame.payload, e.slot as usize, self.seq_len, self.vocab)
                } else {
                    token_hash(e.req_id, a.generated.len() as u32, self.vocab)
                };
                a.generated.push(tok);
                a.prefilled = true;
                match a.first_token_at {
                    None => {
                        a.first_token_at = Some(now);
                        let ttft = Duration::from_secs_f64((now - a.req.arrival).max(0.0));
                        self.ttft_recent.observe(ttft);
                        g.window("serving.ttft_ms").observe(ttft);
                        if let Some(ts) = self.tenant_state(&a.req.tenant) {
                            g.window(&ts.ttft_metric).observe(ttft);
                        }
                    }
                    Some(_) => {
                        let itl = Duration::from_secs_f64((now - a.last_token_at).max(0.0));
                        g.window("serving.itl_ms").observe(itl);
                    }
                }
                a.last_token_at = now;
                self.token_events.observe(Duration::ZERO);
                tokens_out.push((e.req_id, tok));
                if a.generated.len() as u32 >= a.budget {
                    let latency = (now - a.req.arrival).max(0.0);
                    finished.push((
                        Response { id: e.req_id, latency, next_token: tok },
                        a.req.tenant.clone(),
                    ));
                    lane.retiring.push((e.slot, e.req_id));
                    *slot = None;
                }
            }
        }
        g.counter("serving.tokens").add(tokens_out.len() as u64);
        {
            let streams = self.streams.lock().unwrap();
            for (id, tok) in &tokens_out {
                if let Some(stream) = streams.get(id) {
                    stream.push_token(*tok);
                }
            }
        }
        if !finished.is_empty() {
            {
                let mut responses = self.responses.lock().unwrap();
                for (resp, tenant) in &finished {
                    let dur = Duration::from_secs_f64(resp.latency.max(0.0));
                    self.latency.observe(dur);
                    self.recent.observe(dur);
                    if let Some(ts) = self.tenant_state(tenant) {
                        ts.recent.observe(dur);
                        ts.completed.inc();
                    }
                    responses.push_back(resp.clone());
                }
                while responses.len() > RESPONSES_KEEP {
                    responses.pop_front();
                }
            }
            g.counter("serving.completed").add(finished.len() as u64);
            self.timeline.record("completed", finished.len() as f64);
            for (resp, _) in finished {
                let id = resp.id;
                self.resolve(id, Outcome::Response(resp));
            }
        }
    }

    /// Pack up to `batch_size` requests into the model input tensor,
    /// padding by repeating the first row. Malformed batches are an
    /// error (admission rejects them long before this).
    fn pack_batch(&self, reqs: &[Request]) -> anyhow::Result<Tensor> {
        anyhow::ensure!(!reqs.is_empty(), "empty batch");
        anyhow::ensure!(reqs.len() <= self.batch_size, "batch exceeds model batch");
        let mut tokens = Vec::with_capacity(self.batch_size * self.seq_len);
        for r in reqs {
            anyhow::ensure!(
                r.tokens.len() == self.seq_len,
                "request {} seq len {} != {}",
                r.id,
                r.tokens.len(),
                self.seq_len
            );
            tokens.extend_from_slice(&r.tokens);
        }
        for _ in reqs.len()..self.batch_size {
            let row = reqs[0].tokens.clone();
            tokens.extend_from_slice(&row);
        }
        Ok(Tensor::from_i32(&[self.batch_size, self.seq_len], &tokens))
    }

    /// Dispatcher: assign a batch id, register the outstanding entry
    /// (so the collector's sweep owns the batch even if every replica
    /// is down right now), then try to send.
    fn dispatch_batch(&self, batch: Vec<Request>) {
        // Defense in depth, kept cheap (no throwaway tensor pack):
        // admission already rejects malformed requests, so this path
        // should be unreachable.
        let malformed = batch.is_empty()
            || batch.len() > self.batch_size
            || batch.iter().any(|r| r.tokens.len() != self.seq_len);
        if malformed {
            crate::metrics::global().counter("serving.pack_failures").inc();
            for r in batch {
                let got = r.tokens.len();
                self.resolve(
                    r.id,
                    Outcome::Rejected(RejectReason::Malformed { got, want: self.seq_len }),
                );
            }
            return;
        }
        let id = self.next_batch_id.fetch_add(1, Ordering::Relaxed);
        let reqs = batch.clone();
        self.outstanding.lock().unwrap().insert(
            id,
            Outstanding { requests: batch, sent_at: Instant::now(), attempts: 0 },
        );
        if !self.send_batch(id, &reqs) {
            // No live replica: the entry stays outstanding; the sweep
            // redispatches once a replica recovers or scales out.
            self.timeline.record_labeled("stall", 1.0, "no live replica");
        }
    }

    /// Pack and send batch `id` to a live replica, updating the
    /// outstanding entry's clock and attempt count. `false` when every
    /// replica is dead or saturated.
    fn send_batch(&self, id: u64, reqs: &[Request]) -> bool {
        let Ok(tensor) = self.pack_batch(reqs) else { return false };
        let env = Envelope { id, tensor }.pack();
        loop {
            let Some(token) = self.in_router.pick() else {
                return false;
            };
            match self.comm.send_blocking(&token.replica, env.clone(), 1, TAG_DATA) {
                Ok(()) => {
                    self.in_router.complete(&token);
                    if let Some(entry) = self.outstanding.lock().unwrap().get_mut(&id) {
                        entry.sent_at = Instant::now();
                        entry.attempts += 1;
                    }
                    return true;
                }
                Err(_) => {
                    self.in_router.mark_dead(&token.replica);
                }
            }
        }
    }

    /// One collector iteration: fault events, receive posting, harvest,
    /// outstanding sweep. Bounded waits keep the stop flag live.
    fn collect_tick(
        &self,
        events: &Receiver<WorldEvent>,
        pending: &mut HashMap<String, Work>,
    ) {
        // Fault events: drop broken edges from the router/collection.
        while let Ok(evt) = events.try_recv() {
            if let WorldEvent::Broken { world, .. } = evt {
                self.in_router.mark_dead(&world);
                self.out_edges.lock().unwrap().retain(|e| e != &world);
                pending.remove(&world);
                self.timeline.record_labeled("failure", 1.0, &world);
            }
        }
        // (Re-)post receives on the current out-edge set; prune edges
        // that were retired (scale-in) or broke.
        {
            let edges = self.out_edges.lock().unwrap().clone();
            pending.retain(|e, _| edges.contains(e));
            for e in edges {
                if !pending.contains_key(&e) {
                    if let Ok(w) = self.comm.recv(&e, 0, TAG_DATA) {
                        pending.insert(e, w);
                    }
                }
            }
        }
        if pending.is_empty() {
            std::thread::sleep(Duration::from_millis(5));
        } else {
            let names: Vec<String> = pending.keys().cloned().collect();
            let works: Vec<Work> = names.iter().map(|n| pending[n].clone()).collect();
            if let Some(idx) =
                self.comm.wait_any_deadline(&works, Some(Duration::from_millis(20)))
            {
                let edge = names[idx].clone();
                let work = pending.remove(&edge).unwrap();
                match work.wait() {
                    Ok(Some(packed)) => {
                        if let Ok(env) = Envelope::unpack(&packed) {
                            if StepFrame::is_step(&env.tensor) {
                                self.harvest_step(env);
                                // Keep the lane hot: schedule the next
                                // iteration now, not at the scheduler
                                // thread's next pass.
                                self.decode_tick();
                            } else {
                                self.harvest_response(env);
                            }
                        }
                    }
                    Ok(None) => {}
                    Err(e) => {
                        self.mgr.break_world(&edge, &e.to_string());
                    }
                }
            }
        }
        self.sweep_outstanding();
    }

    /// Redispatch stale batches (lost to a dead worker), drop fully
    /// expired ones, give up after `retry_max_attempts`.
    fn sweep_outstanding(&self) {
        let now = since_epoch();
        let mut stale: Vec<(u64, Vec<Request>)> = Vec::new();
        let mut failed: Vec<(u64, Vec<Request>)> = Vec::new();
        let mut expired: Vec<(u64, Vec<Request>)> = Vec::new();
        {
            let out = self.outstanding.lock().unwrap();
            for (id, o) in out.iter() {
                let overdue = o.sent_at.elapsed() > self.retry_timeout
                    || (o.attempts == 0 && o.sent_at.elapsed() > Duration::from_millis(50));
                if !overdue {
                    continue;
                }
                if o.requests.iter().all(|r| r.expired_at(now))
                    && o.requests.iter().any(|r| r.deadline.is_some())
                {
                    expired.push((*id, o.requests.clone()));
                } else if o.attempts >= self.retry_max_attempts {
                    failed.push((*id, o.requests.clone()));
                } else {
                    stale.push((*id, o.requests.clone()));
                }
            }
        }
        for (id, reqs) in expired {
            self.outstanding.lock().unwrap().remove(&id);
            crate::metrics::global()
                .counter("serving.dropped.deadline")
                .add(reqs.len() as u64);
            self.timeline.record_labeled("expired", 1.0, &format!("batch {id}"));
            for r in reqs {
                if let Some(ts) = self.tenant_state(&r.tenant) {
                    ts.dropped_deadline.inc();
                }
                self.resolve(r.id, Outcome::Dropped(DropReason::Deadline));
            }
        }
        for (id, reqs) in failed {
            self.outstanding.lock().unwrap().remove(&id);
            crate::metrics::global()
                .counter("serving.dropped.failed")
                .add(reqs.len() as u64);
            self.timeline.record_labeled("gave_up", 1.0, &format!("batch {id}"));
            for r in reqs {
                self.resolve(r.id, Outcome::Dropped(DropReason::Failed));
            }
        }
        for (id, reqs) in stale {
            self.retries.fetch_add(1, Ordering::Relaxed);
            self.timeline.record_labeled("retry", 1.0, &format!("batch {id}"));
            if !self.send_batch(id, &reqs) {
                break; // nothing alive; the next sweep retries
            }
        }
    }

    fn harvest_response(&self, env: Envelope) {
        let taken = self.outstanding.lock().unwrap().remove(&env.id);
        let Some(out) = taken else {
            return; // duplicate (retry raced with the original) — dedupe
        };
        let logits = env.tensor; // [B, S, V]
        // Forward-only pipelines echo the (i32) input instead of
        // producing logits; answer with token 0 rather than decoding.
        let decodable = logits.dtype() == DType::F32
            && logits.elems() >= self.batch_size * self.seq_len * self.vocab;
        let now = since_epoch();
        let n_done = {
            let mut responses = self.responses.lock().unwrap();
            for (row, req) in out.requests.iter().enumerate() {
                let next_token = if decodable {
                    argmax_last(&logits, row, self.seq_len, self.vocab)
                } else {
                    0
                };
                let latency = now - req.arrival;
                let dur = Duration::from_secs_f64(latency.max(0.0));
                self.latency.observe(dur);
                self.recent.observe(dur);
                if let Some(ts) = self.tenant_state(&req.tenant) {
                    ts.recent.observe(dur);
                    ts.completed.inc();
                }
                let resp = Response { id: req.id, latency, next_token };
                responses.push_back(resp.clone());
                self.resolve(req.id, Outcome::Response(resp));
            }
            // The runtime is always-on: bound the introspection buffer
            // (O(excess) on a deque, not a front-shift of the whole
            // buffer on every harvest once the cap is reached).
            while responses.len() > RESPONSES_KEEP {
                responses.pop_front();
            }
            responses.len()
        };
        // (The serving.recent_p99_ms gauge is refreshed by the
        // autoscaler tick, which computes the window quantile anyway —
        // not here, where it would cost a sort per harvested batch.)
        crate::metrics::global()
            .counter("serving.completed")
            .add(out.requests.len() as u64);
        self.timeline.record("completed", n_done as f64);
    }

    // ------------------------------------------------------------------
    // Compatibility serve: submit-all, wait-all, report.
    // ------------------------------------------------------------------

    /// Serve `requests` (arriving at `rate` rps, or open-loop) and block
    /// until every one resolved or `deadline` passes. Built entirely on
    /// the submit API; admission blocks for queue space (no shedding),
    /// so a bounded queue backpressures this closed loop instead of
    /// rejecting it.
    pub fn serve(
        self: &Arc<Self>,
        requests: Vec<Request>,
        rate: Option<f64>,
        deadline: Duration,
    ) -> LeaderReport {
        self.start_runtime();
        let t_start = Instant::now();
        let hard_deadline = t_start + deadline;
        let retries_before = self.retries.load(Ordering::Relaxed);
        let mut rng = crate::util::prng::Rng::new(0xFEED);
        let mut handles = Vec::with_capacity(requests.len());
        for r in requests {
            if let Some(rate) = rate {
                std::thread::sleep(Duration::from_secs_f64(rng.exp(rate)));
            }
            handles.push(self.admit(r, true));
        }
        let mut completed = 0usize;
        let mut rejected = 0usize;
        let mut dropped = 0usize;
        let mut unresolved: Vec<u64> = Vec::new();
        // Per-run latency distribution from this run's own responses —
        // the cumulative `self.latency` would pollute a second serve()
        // call's report with the first call's samples.
        let run_latency = Histogram::default();
        for h in &handles {
            match h.wait_deadline(hard_deadline) {
                Some(Outcome::Response(resp)) => {
                    completed += 1;
                    run_latency
                        .observe(Duration::from_secs_f64(resp.latency.max(0.0)));
                }
                Some(Outcome::Rejected(_)) => rejected += 1,
                Some(Outcome::Dropped(_)) => dropped += 1,
                None => unresolved.push(h.id()),
            }
        }
        if !unresolved.is_empty() {
            // Run deadline passed: abandon what never resolved so a
            // later run reusing ids cannot collide with this one.
            dropped += unresolved.len();
            self.abandon(&unresolved);
        }
        let duration = t_start.elapsed().as_secs_f64();
        LeaderReport {
            completed,
            rejected,
            dropped,
            duration,
            throughput_rps: completed as f64 / duration.max(1e-9),
            p50_ms: run_latency.quantile_us(0.50) as f64 / 1e3,
            p99_ms: run_latency.quantile_us(0.99) as f64 / 1e3,
            mean_ms: run_latency.mean_us() / 1e3,
            retries: self.retries.load(Ordering::Relaxed) - retries_before,
        }
    }

    /// Walk away from requests the caller stopped waiting for: purge
    /// them from the admission queue, drop outstanding batches made up
    /// *entirely* of them, resolve their handles as abandoned. Mixed
    /// batches (a concurrent submitter's requests packed alongside
    /// abandoned ones) stay outstanding so the foreign requests still
    /// complete; the abandoned members' late responses hit the resolve
    /// no-op path.
    fn abandon(&self, ids: &[u64]) {
        let _ = self.batcher.purge(ids);
        let _ = self.stream_batcher.purge(ids);
        self.outstanding
            .lock()
            .unwrap()
            .retain(|_, o| !o.requests.iter().all(|r| ids.contains(&r.id)));
        for &id in ids {
            self.resolve(id, Outcome::Dropped(DropReason::Abandoned));
        }
    }

    // ------------------------------------------------------------------
    // Introspection + autoscaler signals.
    // ------------------------------------------------------------------

    /// Responses collected so far (test introspection; bounded to the
    /// most recent `RESPONSES_KEEP`).
    pub fn responses(&self) -> Vec<Response> {
        self.responses.lock().unwrap().iter().cloned().collect()
    }

    /// Current queue depth per alive stage-0 replica (scaling signal).
    pub fn depth_per_replica(&self) -> f64 {
        let (alive, _) = self.in_router.counts();
        if alive == 0 {
            f64::INFINITY
        } else {
            self.queue_depth() as f64 / alive as f64
        }
    }

    /// Admission queue depth right now: both queues, plus streaming
    /// requests waiting to re-admit after their lane died.
    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
            + self.stream_batcher.depth()
            + self.decode.lock().unwrap().requeue.len()
    }

    /// Alive stage-0 replicas (router liveness).
    pub fn alive_replicas(&self) -> usize {
        self.in_router.counts().0
    }

    /// Dispatched batches not yet answered, plus decode-lane residents
    /// and step frames in flight (so scale-in drain waits for them).
    pub fn outstanding_batches(&self) -> usize {
        let decode_busy = {
            let st = self.decode.lock().unwrap();
            st.lanes
                .values()
                .map(|l| l.occupied() + usize::from(l.inflight.is_some()))
                .sum::<usize>()
        };
        self.outstanding.lock().unwrap().len() + decode_busy
    }

    /// p99 latency (ms) over the recent sliding window (0 when idle).
    pub fn recent_p99_ms(&self) -> f64 {
        self.recent.quantile_us(0.99) as f64 / 1e3
    }

    /// p99 time-to-first-token (ms) over the recent window (0 when
    /// idle) — the decode loop's admission-side SLO signal.
    pub fn recent_ttft_p99_ms(&self) -> f64 {
        self.ttft_recent.quantile_us(0.99) as f64 / 1e3
    }

    /// Decoded tokens per second over the recent window — the decode
    /// loop's throughput signal.
    pub fn tokens_per_s(&self) -> f64 {
        self.token_events.count() as f64 / self.token_window.as_secs_f64().max(1e-9)
    }

    /// Per-tenant autoscaler signals: queue depth summed across both
    /// admission queues, recent p99, and the tenant's SLO target so
    /// breach attribution can name the tenant driving a scale-out.
    /// Empty on a single-tenant runtime.
    pub fn tenant_signals(&self) -> Vec<super::autoscaler::TenantSignal> {
        if self.tenants.is_empty() {
            return Vec::new();
        }
        let mut depths: BTreeMap<TenantId, usize> = BTreeMap::new();
        for (t, d) in self.batcher.tenant_depths() {
            *depths.entry(t).or_default() += d;
        }
        for (t, d) in self.stream_batcher.tenant_depths() {
            *depths.entry(t).or_default() += d;
        }
        self.tenants
            .iter()
            .map(|(t, ts)| super::autoscaler::TenantSignal {
                tenant: t.as_str().to_string(),
                depth: depths.get(t).copied().unwrap_or(0),
                p99_ms: ts.recent.quantile_us(0.99) as f64 / 1e3,
                slo_ms: ts.slo_ms,
            })
            .collect()
    }

    /// Per-in-edge dispatch totals (router introspection).
    pub fn dispatch_counts(&self) -> BTreeMap<String, u64> {
        self.in_router.dispatch_counts()
    }

    /// Stop routing new batches to these in-edges (graceful scale-in
    /// drain; in-flight batches still complete over the out-edges).
    pub fn quiesce_edges(&self, edges: &[String]) {
        for e in edges {
            self.in_router.remove_replica(e);
        }
    }

    /// Undo a quiesce (the retirement failed): route to these in-edges
    /// again.
    pub fn restore_edges(&self, edges: &[String]) {
        for e in edges {
            self.in_router.add_replica(e);
        }
    }

    /// Forget retired edges entirely (drain complete): stop collecting
    /// on the out-edges too.
    pub fn release_edges(&self, edges: &[String]) {
        for e in edges {
            self.in_router.remove_replica(e);
        }
        self.out_edges.lock().unwrap().retain(|e| !edges.contains(e));
    }
}

impl Drop for Leader {
    fn drop(&mut self) {
        // Signal the runtime threads (they hold only Weak refs and the
        // batcher) and detach them — joining here could deadlock when
        // the last Arc is dropped by one of them.
        self.stop.store(true, Ordering::Relaxed);
        self.batcher.close();
        self.stream_batcher.close();
        let _ = self.runtime.lock().unwrap().take();
        let _ = self.decode_thread.lock().unwrap().take();
        // Clients may outlive the leader (handles own only the slot /
        // stream): resolve everything still pending so no wait() or
        // next_event() loop hangs forever.
        let unresolved: Vec<Arc<OutcomeSlot>> =
            self.handles.lock().unwrap().drain().map(|(_, s)| s).collect();
        for slot in unresolved {
            slot.resolve(Outcome::Dropped(DropReason::Shutdown));
        }
        let leftover: Vec<Arc<TokenStream>> =
            self.streams.lock().unwrap().drain().map(|(_, s)| s).collect();
        for stream in leftover {
            stream.finish(Outcome::Dropped(DropReason::Shutdown));
        }
    }
}

/// Argmax over the vocab at the last sequence position of `row`.
fn argmax_last(logits: &Tensor, row: usize, seq_len: usize, vocab: usize) -> i32 {
    let data = logits.as_f32();
    let base = row * seq_len * vocab + (seq_len - 1) * vocab;
    let slice = &data[base..base + vocab];
    let mut best = 0usize;
    for (i, &v) in slice.iter().enumerate() {
        if v > slice[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_last_position() {
        // B=1, S=2, V=4; row 0, last position has max at index 2.
        let logits = Tensor::from_f32(
            &[1, 2, 4],
            &[9.0, 0.0, 0.0, 0.0, 0.1, 0.2, 5.0, 0.3],
        );
        assert_eq!(argmax_last(&logits, 0, 2, 4), 2);
    }
}
