//! The leader: request ingestion, dynamic batching, dispatch into the
//! pipeline, response collection, retry on loss, and SLO accounting.
//!
//! The leader is rank 0 of each `in-*` world (feeding stage-0 replicas)
//! and rank 1 of each `out-*` world (hearing from last-stage replicas).
//! Batches carry an id in their [`Envelope`]; responses are correlated
//! by id, so replicated stages may reorder freely. Lost batches (a
//! worker died while holding them) are re-dispatched after
//! `retry_timeout` — at-least-once with response dedupe.

use super::batcher::DynamicBatcher;
use super::request::{Request, Response};
use super::router::ReplicaRouter;
use super::stage_worker::{Envelope, TAG_DATA};
use super::topology::{NodeId, Topology, WorldDef};
use crate::metrics::{Histogram, Timeline};
use crate::multiworld::{WorldCommunicator, WorldEvent, WorldManager};
use crate::mwccl::{Work, WorldOptions};
use crate::tensor::{DType, Tensor};
use crate::util::time::since_epoch;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

struct Outstanding {
    requests: Vec<Request>,
    sent_at: Instant,
    attempts: u32,
}

/// See module docs.
pub struct Leader {
    mgr: WorldManager,
    comm: WorldCommunicator,
    pub batcher: Arc<DynamicBatcher>,
    in_router: ReplicaRouter,
    out_edges: Mutex<Vec<String>>,
    batch_size: usize,
    seq_len: usize,
    vocab: usize,
    next_batch_id: AtomicU64,
    outstanding: Mutex<HashMap<u64, Outstanding>>,
    responses: Mutex<Vec<Response>>,
    pub latency: Histogram,
    pub timeline: Timeline,
    retry_timeout: Duration,
    stop: Arc<AtomicBool>,
}

/// Final numbers for a serve run.
#[derive(Clone, Debug)]
pub struct LeaderReport {
    pub completed: usize,
    pub duration: f64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub retries: u64,
}

impl Leader {
    /// Join the leader's worlds and set up the data structures.
    /// `batch_size`/`seq_len`/`vocab` come from the model manifest.
    pub fn new(
        mgr: WorldManager,
        topo: &Topology,
        opts: &WorldOptions,
        batch_size: usize,
        seq_len: usize,
        vocab: usize,
        cfg: &crate::config::ServingConfig,
    ) -> anyhow::Result<Arc<Leader>> {
        super::stage_worker::init_node_worlds(&mgr, topo, NodeId::Leader, opts)?;
        let comm = mgr.communicator();
        let in_router = ReplicaRouter::new(cfg.replica_inflight);
        for w in topo.out_edges(NodeId::Leader) {
            in_router.add_replica(&w.name);
        }
        let out_edges: Vec<String> = topo
            .in_edges(NodeId::Leader)
            .iter()
            .map(|w| w.name.clone())
            .collect();
        Ok(Arc::new(Leader {
            mgr,
            comm,
            batcher: DynamicBatcher::new(
                batch_size,
                Duration::from_millis(cfg.batch_timeout_ms),
            ),
            in_router,
            out_edges: Mutex::new(out_edges),
            batch_size,
            seq_len,
            vocab,
            next_batch_id: AtomicU64::new(1),
            outstanding: Mutex::new(HashMap::new()),
            responses: Mutex::new(Vec::new()),
            latency: Histogram::default(),
            timeline: Timeline::new(),
            retry_timeout: Duration::from_secs(2),
            stop: Arc::new(AtomicBool::new(false)),
        }))
    }

    /// The manager (for event wiring by the controller).
    pub fn manager(&self) -> &WorldManager {
        &self.mgr
    }

    /// Join a fresh world created by online instantiation (the leader's
    /// side; called by the controller).
    pub fn join_world(&self, def: &WorldDef, opts: &WorldOptions) -> anyhow::Result<()> {
        let rank = def
            .rank_of(NodeId::Leader)
            .ok_or_else(|| anyhow::anyhow!("leader not in {}", def.name))?;
        let addr: std::net::SocketAddr =
            format!("127.0.0.1:{}", def.store_port).parse().unwrap();
        self.mgr
            .initialize_world(&def.name, rank, def.size(), addr, opts.clone())?;
        if rank == 0 {
            self.in_router.add_replica(&def.name);
        } else {
            self.out_edges.lock().unwrap().push(def.name.clone());
        }
        Ok(())
    }

    /// Pack up to `batch_size` requests into the model input tensor,
    /// padding by repeating the first row.
    fn pack_batch(&self, reqs: &[Request]) -> Tensor {
        let mut tokens = Vec::with_capacity(self.batch_size * self.seq_len);
        for r in reqs {
            assert_eq!(r.tokens.len(), self.seq_len, "request seq len");
            tokens.extend_from_slice(&r.tokens);
        }
        for _ in reqs.len()..self.batch_size {
            let row = reqs[0].tokens.clone();
            tokens.extend_from_slice(&row);
        }
        Tensor::from_i32(&[self.batch_size, self.seq_len], &tokens)
    }

    fn dispatch(&self, id: u64, reqs: Vec<Request>) -> bool {
        let tensor = self.pack_batch(&reqs);
        let env = Envelope { id, tensor }.pack();
        loop {
            let Some(edge) = self.in_router.pick() else {
                return false; // everything dead/saturated
            };
            match self.comm.send_blocking(&edge, env.clone(), 1, TAG_DATA) {
                Ok(()) => {
                    self.in_router.complete(&edge);
                    let attempts = {
                        let mut out = self.outstanding.lock().unwrap();
                        let entry = out.entry(id).or_insert(Outstanding {
                            requests: reqs.clone(),
                            sent_at: Instant::now(),
                            attempts: 0,
                        });
                        entry.sent_at = Instant::now();
                        entry.attempts += 1;
                        entry.attempts
                    };
                    let _ = attempts;
                    return true;
                }
                Err(_) => {
                    self.in_router.mark_dead(&edge);
                }
            }
        }
    }

    fn harvest_response(&self, env: Envelope) {
        let taken = self.outstanding.lock().unwrap().remove(&env.id);
        let Some(out) = taken else {
            return; // duplicate (retry raced with the original) — dedupe
        };
        let logits = env.tensor; // [B, S, V]
        // Forward-only pipelines echo the (i32) input instead of
        // producing logits; answer with token 0 rather than decoding.
        let decodable = logits.dtype() == DType::F32
            && logits.elems() >= self.batch_size * self.seq_len * self.vocab;
        let now = since_epoch();
        let mut responses = self.responses.lock().unwrap();
        for (row, req) in out.requests.iter().enumerate() {
            let next_token = if decodable {
                argmax_last(&logits, row, self.seq_len, self.vocab)
            } else {
                0
            };
            let latency = now - req.arrival;
            self.latency
                .observe(Duration::from_secs_f64(latency.max(0.0)));
            responses.push(Response { id: req.id, latency, next_token });
        }
        self.timeline
            .record("completed", responses.len() as f64);
    }

    /// Serve `requests` (arriving at `rate` rps, or open-loop) and block
    /// until all responses are in or `deadline` passes.
    pub fn serve(
        self: &Arc<Self>,
        requests: Vec<Request>,
        rate: Option<f64>,
        deadline: Duration,
    ) -> LeaderReport {
        let t_start = Instant::now();
        let total = requests.len();
        let mut retries = 0u64;

        // Ingest thread: requests → batcher at the given rate.
        let batcher = self.batcher.clone();
        let ingest = {
            let mut rng = crate::util::prng::Rng::new(0xFEED);
            std::thread::spawn(move || {
                for mut r in requests {
                    if let Some(rate) = rate {
                        std::thread::sleep(Duration::from_secs_f64(rng.exp(rate)));
                    }
                    r.arrival = since_epoch();
                    batcher.push(r);
                }
                batcher.close();
            })
        };

        // Dispatch thread: batches → pipeline.
        let me = self.clone();
        let dispatcher = std::thread::spawn(move || {
            while let Some(batch) = me.batcher.next_batch() {
                let id = me.next_batch_id.fetch_add(1, Ordering::Relaxed);
                if !me.dispatch(id, batch) {
                    break; // pipeline dead
                }
            }
        });

        // Collect loop (this thread): post irecv on every out-edge, poll.
        let hard_deadline = Instant::now() + deadline;
        let mut pending: HashMap<String, Work> = HashMap::new();
        let events = self.mgr.subscribe();
        while self.responses.lock().unwrap().len() < total {
            if Instant::now() >= hard_deadline {
                break;
            }
            // Fault events: drop broken edges from the router/collection.
            while let Ok(evt) = events.try_recv() {
                if let WorldEvent::Broken { world, .. } = evt {
                    self.in_router.mark_dead(&world);
                    self.out_edges.lock().unwrap().retain(|e| e != &world);
                    pending.remove(&world);
                    self.timeline.record_labeled("failure", 1.0, &world);
                }
            }
            // (Re-)post receives.
            {
                let edges = self.out_edges.lock().unwrap().clone();
                for e in edges {
                    if !pending.contains_key(&e) {
                        if let Ok(w) = self.comm.recv(&e, 0, TAG_DATA) {
                            pending.insert(e, w);
                        }
                    }
                }
            }
            if pending.is_empty() {
                std::thread::sleep(Duration::from_millis(5));
            } else {
                let names: Vec<String> = pending.keys().cloned().collect();
                let works: Vec<Work> = names.iter().map(|n| pending[n].clone()).collect();
                if let Some(idx) =
                    self.comm.wait_any_deadline(&works, Some(Duration::from_millis(20)))
                {
                    let edge = names[idx].clone();
                    let work = pending.remove(&edge).unwrap();
                    match work.wait() {
                        Ok(Some(packed)) => {
                            if let Ok(env) = Envelope::unpack(&packed) {
                                self.harvest_response(env);
                            }
                        }
                        Ok(None) => {}
                        Err(e) => {
                            self.mgr.break_world(&edge, &e.to_string());
                        }
                    }
                }
            }
            // Retry stale outstanding batches (lost to a dead worker).
            let stale: Vec<(u64, Vec<Request>)> = {
                let out = self.outstanding.lock().unwrap();
                out.iter()
                    .filter(|(_, o)| o.sent_at.elapsed() > self.retry_timeout && o.attempts < 5)
                    .map(|(id, o)| (*id, o.requests.clone()))
                    .collect()
            };
            for (id, reqs) in stale {
                retries += 1;
                self.timeline.record_labeled("retry", 1.0, &format!("batch {id}"));
                if !self.dispatch(id, reqs) {
                    break;
                }
            }
        }
        self.stop.store(true, Ordering::Relaxed);
        let _ = ingest.join();
        self.batcher.close();
        let _ = dispatcher.join();

        let completed = self.responses.lock().unwrap().len();
        let duration = t_start.elapsed().as_secs_f64();
        LeaderReport {
            completed,
            duration,
            throughput_rps: completed as f64 / duration,
            p50_ms: self.latency.quantile_us(0.50) as f64 / 1e3,
            p99_ms: self.latency.quantile_us(0.99) as f64 / 1e3,
            mean_ms: self.latency.mean_us() / 1e3,
            retries,
        }
    }

    /// Responses collected so far (test introspection).
    pub fn responses(&self) -> Vec<Response> {
        self.responses.lock().unwrap().clone()
    }

    /// Current queue depth per alive stage-0 replica (scaling signal).
    pub fn depth_per_replica(&self) -> f64 {
        let (alive, _) = self.in_router.counts();
        if alive == 0 {
            f64::INFINITY
        } else {
            self.batcher.depth() as f64 / alive as f64
        }
    }
}

/// Argmax over the vocab at the last sequence position of `row`.
fn argmax_last(logits: &Tensor, row: usize, seq_len: usize, vocab: usize) -> i32 {
    let data = logits.as_f32();
    let base = row * seq_len * vocab + (seq_len - 1) * vocab;
    let slice = &data[base..base + vocab];
    let mut best = 0usize;
    for (i, &v) in slice.iter().enumerate() {
        if v > slice[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_last_position() {
        // B=1, S=2, V=4; row 0, last position has max at index 2.
        let logits = Tensor::from_f32(
            &[1, 2, 4],
            &[9.0, 0.0, 0.0, 0.0, 0.1, 0.2, 5.0, 0.3],
        );
        assert_eq!(argmax_last(&logits, 0, 2, 4), 2);
    }
}
