//! Inference requests, responses, the client-facing request handle and
//! the synthetic workload generator.
//!
//! A request submitted to the always-on serving runtime resolves to one
//! [`Outcome`] exactly once: a [`Response`] when logits came back, a
//! [`DropReason`] when the runtime gave up on it (SLO deadline expiry in
//! the admission queue, too many redispatch attempts, shutdown), or a
//! [`RejectReason`] when admission control refused it up front (queue
//! full under load-shedding, malformed sequence length, duplicate
//! in-flight id). Callers hold a [`RequestHandle`] and block on
//! [`RequestHandle::wait`] (or poll [`RequestHandle::try_outcome`]).

use crate::util::prng::Rng;
use crate::util::time::since_epoch;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// One inference request: a token sequence for the model.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Arrival time (seconds since experiment epoch); re-stamped at
    /// admission.
    pub arrival: f64,
    /// SLO deadline (seconds since experiment epoch); `None` = no SLO.
    /// Stamped at admission from `ServingConfig::slo_ms`. Requests past
    /// their deadline are dropped in the admission queue *before*
    /// dispatch — never after a wasted forward pass.
    pub deadline: Option<f64>,
}

impl Request {
    pub fn new(id: u64, tokens: Vec<i32>) -> Self {
        Request { id, tokens, arrival: since_epoch(), deadline: None }
    }

    /// Past its SLO deadline at time `now` (seconds since epoch)?
    pub fn expired_at(&self, now: f64) -> bool {
        self.deadline.is_some_and(|d| now > d)
    }
}

/// The serving result for one request.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub id: u64,
    /// Seconds from arrival to completion.
    pub latency: f64,
    /// Argmax token at the last position (the "answer"; enough to prove
    /// real logits flowed back).
    pub next_token: i32,
}

/// Why the runtime dropped an admitted request without a response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropReason {
    /// SLO deadline expired while queued (dropped before dispatch).
    Deadline,
    /// Redispatch attempts exhausted (the pipeline kept losing it).
    Failed,
    /// The runtime shut down while the request was still queued.
    Shutdown,
    /// The caller stopped waiting (compatibility `serve` past its run
    /// deadline).
    Abandoned,
}

/// Why admission control refused a request up front.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The admission queue is at `ServingConfig::admission_depth` and
    /// the runtime is load-shedding.
    QueueFull,
    /// The token sequence does not match the model's sequence length.
    Malformed { got: usize, want: usize },
    /// Another in-flight request already uses this id.
    DuplicateId,
}

/// What a submitted request resolved to. Exactly one outcome per
/// request, delivered through its [`RequestHandle`].
#[derive(Clone, Debug, PartialEq)]
pub enum Outcome {
    Response(Response),
    Dropped(DropReason),
    Rejected(RejectReason),
}

impl Outcome {
    pub fn response(&self) -> Option<&Response> {
        match self {
            Outcome::Response(r) => Some(r),
            _ => None,
        }
    }

    pub fn is_response(&self) -> bool {
        matches!(self, Outcome::Response(_))
    }
}

/// Shared once-only outcome slot between the runtime and the handle.
#[derive(Default)]
pub(crate) struct OutcomeSlot {
    state: Mutex<Option<Outcome>>,
    cv: Condvar,
}

impl OutcomeSlot {
    /// First resolution wins; later calls are no-ops (e.g. a retry's
    /// duplicate response racing a deadline drop).
    pub(crate) fn resolve(&self, outcome: Outcome) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.is_some() {
            return false;
        }
        *st = Some(outcome);
        self.cv.notify_all();
        true
    }
}

/// The client's side of a submitted request. See module docs.
pub struct RequestHandle {
    id: u64,
    slot: Arc<OutcomeSlot>,
}

impl RequestHandle {
    pub(crate) fn new(id: u64, slot: Arc<OutcomeSlot>) -> Self {
        RequestHandle { id, slot }
    }

    /// Handle whose outcome is already known (admission rejection).
    pub(crate) fn resolved(id: u64, outcome: Outcome) -> Self {
        let slot = Arc::new(OutcomeSlot::default());
        slot.resolve(outcome);
        RequestHandle { id, slot }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// The outcome if already resolved (non-blocking).
    pub fn try_outcome(&self) -> Option<Outcome> {
        self.slot.state.lock().unwrap().clone()
    }

    /// Block until the request resolves.
    pub fn wait(&self) -> Outcome {
        let mut st = self.slot.state.lock().unwrap();
        loop {
            if let Some(o) = st.as_ref() {
                return o.clone();
            }
            st = self.slot.cv.wait(st).unwrap();
        }
    }

    /// Block until the request resolves or `deadline` passes.
    pub fn wait_deadline(&self, deadline: Instant) -> Option<Outcome> {
        let mut st = self.slot.state.lock().unwrap();
        loop {
            if let Some(o) = st.as_ref() {
                return Some(o.clone());
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, timeout) = self
                .slot
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = g;
            if timeout.timed_out() && st.is_none() {
                return None;
            }
        }
    }
}

/// Poisson-arrival synthetic workload: fixed-length uniform-random token
/// sequences, exponential inter-arrival gaps.
pub struct RequestGen {
    rng: Rng,
    next_id: u64,
    pub seq_len: usize,
    pub vocab: usize,
    /// Requests per second (None = as-fast-as-possible).
    pub rate: Option<f64>,
}

impl RequestGen {
    pub fn new(seed: u64, seq_len: usize, vocab: usize, rate: Option<f64>) -> Self {
        RequestGen { rng: Rng::new(seed), next_id: 0, seq_len, vocab, rate }
    }

    /// Produce the next request, returning the inter-arrival delay the
    /// caller should sleep before injecting it (0 for open-loop max
    /// rate).
    pub fn next(&mut self) -> (Request, std::time::Duration) {
        let tokens: Vec<i32> = (0..self.seq_len)
            .map(|_| self.rng.below(self.vocab as u64) as i32)
            .collect();
        let id = self.next_id;
        self.next_id += 1;
        let gap = match self.rate {
            Some(r) => std::time::Duration::from_secs_f64(self.rng.exp(r)),
            None => std::time::Duration::ZERO,
        };
        (Request::new(id, tokens), gap)
    }

    /// Generate `n` requests eagerly (benchmark setup path).
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next().0).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn ids_are_sequential_and_tokens_in_range() {
        let mut g = RequestGen::new(1, 16, 256, None);
        let reqs = g.take(50);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), 16);
            assert!(r.tokens.iter().all(|&t| (0..256).contains(&t)));
        }
    }

    #[test]
    fn poisson_gaps_have_correct_mean() {
        let mut g = RequestGen::new(2, 4, 16, Some(100.0));
        let n = 5000;
        let total: f64 = (0..n).map(|_| g.next().1.as_secs_f64()).sum();
        let mean = total / n as f64;
        assert!((mean - 0.01).abs() < 0.002, "mean gap {mean}");
    }

    #[test]
    fn open_loop_has_zero_gap() {
        let mut g = RequestGen::new(3, 4, 16, None);
        assert_eq!(g.next().1, std::time::Duration::ZERO);
    }

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<_> = RequestGen::new(7, 8, 64, None).take(10);
        let b: Vec<_> = RequestGen::new(7, 8, 64, None).take(10);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn deadline_expiry() {
        let mut r = Request::new(1, vec![0; 4]);
        assert!(!r.expired_at(r.arrival + 1e9), "no deadline, never expires");
        r.deadline = Some(r.arrival + 0.5);
        assert!(!r.expired_at(r.arrival + 0.4));
        assert!(r.expired_at(r.arrival + 0.6));
    }

    #[test]
    fn handle_resolves_once() {
        let slot = Arc::new(OutcomeSlot::default());
        let h = RequestHandle::new(7, slot.clone());
        assert!(h.try_outcome().is_none());
        assert!(slot.resolve(Outcome::Dropped(DropReason::Deadline)));
        assert!(
            !slot.resolve(Outcome::Response(Response {
                id: 7,
                latency: 0.0,
                next_token: 0
            })),
            "second resolution is a no-op"
        );
        assert_eq!(h.wait(), Outcome::Dropped(DropReason::Deadline));
        assert_eq!(h.id(), 7);
    }

    #[test]
    fn handle_wait_crosses_threads() {
        let slot = Arc::new(OutcomeSlot::default());
        let h = RequestHandle::new(1, slot.clone());
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            slot.resolve(Outcome::Response(Response {
                id: 1,
                latency: 0.02,
                next_token: 3
            }));
        });
        let got = h.wait();
        t.join().unwrap();
        assert!(got.is_response());
        assert_eq!(got.response().unwrap().next_token, 3);
    }

    #[test]
    fn handle_wait_deadline_times_out_then_resolves() {
        let slot = Arc::new(OutcomeSlot::default());
        let h = RequestHandle::new(1, slot.clone());
        assert!(h
            .wait_deadline(Instant::now() + Duration::from_millis(20))
            .is_none());
        slot.resolve(Outcome::Dropped(DropReason::Shutdown));
        assert_eq!(
            h.wait_deadline(Instant::now() + Duration::from_millis(20)),
            Some(Outcome::Dropped(DropReason::Shutdown))
        );
    }

    #[test]
    fn pre_resolved_handle() {
        let h = RequestHandle::resolved(9, Outcome::Rejected(RejectReason::QueueFull));
        assert_eq!(h.try_outcome(), Some(Outcome::Rejected(RejectReason::QueueFull)));
    }
}
