//! Inference requests, responses, the client-facing request handle and
//! the synthetic workload generator.
//!
//! A request submitted to the always-on serving runtime resolves to one
//! [`Outcome`] exactly once: a [`Response`] when logits came back, a
//! [`DropReason`] when the runtime gave up on it (SLO deadline expiry in
//! the admission queue, too many redispatch attempts, shutdown), or a
//! [`RejectReason`] when admission control refused it up front (queue
//! full under load-shedding, malformed sequence length, duplicate
//! in-flight id). Callers hold a [`RequestHandle`] and block on
//! [`RequestHandle::wait`] (or poll [`RequestHandle::try_outcome`]).
//!
//! Multi-token requests (`max_tokens > 1`) additionally *stream*: the
//! handle yields each decoded token as the iteration that produced it
//! is harvested ([`RequestHandle::next_event`] →
//! [`StreamEvent::Token`]), and the final [`Outcome`] arrives as
//! [`StreamEvent::Done`]. One-shot requests keep the exact legacy
//! surface — no stream is ever attached.

use crate::util::prng::Rng;
use crate::util::time::since_epoch;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Name of the implicit tenant every request belongs to unless the
/// caller says otherwise. Keeps the single-tenant runtime byte-identical
/// when no tenant table is configured.
pub const DEFAULT_TENANT: &str = "default";

/// A tenant name. Cheap to clone (shared `Arc<str>`) because every
/// request, sub-queue key and per-tenant metric carries one.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TenantId(Arc<str>);

impl TenantId {
    pub fn new(name: &str) -> Self {
        TenantId(Arc::from(name))
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }

    /// The implicit [`DEFAULT_TENANT`]?
    pub fn is_default(&self) -> bool {
        &*self.0 == DEFAULT_TENANT
    }
}

impl Default for TenantId {
    fn default() -> Self {
        TenantId::new(DEFAULT_TENANT)
    }
}

impl std::fmt::Display for TenantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl From<&str> for TenantId {
    fn from(name: &str) -> Self {
        TenantId::new(name)
    }
}

/// One inference request: a token sequence for the model.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Owning tenant. Defaults to [`DEFAULT_TENANT`]; only meaningful
    /// when the runtime has a tenant table (`MW_TENANTS`) — unknown
    /// tenants fold into the default class.
    pub tenant: TenantId,
    /// Arrival time (seconds since experiment epoch); re-stamped at
    /// admission.
    pub arrival: f64,
    /// SLO deadline (seconds since experiment epoch); `None` = no SLO.
    /// Stamped at admission from `ServingConfig::slo_ms`. Requests past
    /// their deadline are dropped in the admission queue *before*
    /// dispatch — never after a wasted forward pass.
    pub deadline: Option<f64>,
    /// Decode budget: tokens to generate. `1` (the default) is the
    /// legacy one-shot request — a single forward pass, no decode loop.
    /// `> 1` routes the request through the streaming decode scheduler.
    pub max_tokens: u32,
}

impl Request {
    pub fn new(id: u64, tokens: Vec<i32>) -> Self {
        Request {
            id,
            tokens,
            tenant: TenantId::default(),
            arrival: since_epoch(),
            deadline: None,
            max_tokens: 1,
        }
    }

    /// Builder: set the decode budget (clamped to ≥ 1).
    pub fn with_max_tokens(mut self, n: u32) -> Self {
        self.max_tokens = n.max(1);
        self
    }

    /// Builder: tag the request with a tenant.
    pub fn with_tenant(mut self, tenant: &str) -> Self {
        self.tenant = TenantId::new(tenant);
        self
    }

    /// Past its SLO deadline at time `now` (seconds since epoch)?
    pub fn expired_at(&self, now: f64) -> bool {
        self.deadline.is_some_and(|d| now > d)
    }
}

/// The serving result for one request.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub id: u64,
    /// Seconds from arrival to completion.
    pub latency: f64,
    /// Argmax token at the last position (the "answer"; enough to prove
    /// real logits flowed back).
    pub next_token: i32,
}

/// Why the runtime dropped an admitted request without a response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropReason {
    /// SLO deadline expired while queued (dropped before dispatch).
    Deadline,
    /// Redispatch attempts exhausted (the pipeline kept losing it).
    Failed,
    /// The runtime shut down while the request was still queued.
    Shutdown,
    /// The caller stopped waiting (compatibility `serve` past its run
    /// deadline).
    Abandoned,
}

/// Why admission control refused a request up front.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The admission queue is at `ServingConfig::admission_depth` and
    /// the runtime is load-shedding.
    QueueFull,
    /// The token sequence does not match the model's sequence length.
    Malformed { got: usize, want: usize },
    /// Another in-flight request already uses this id.
    DuplicateId,
}

/// What a submitted request resolved to. Exactly one outcome per
/// request, delivered through its [`RequestHandle`].
#[derive(Clone, Debug, PartialEq)]
pub enum Outcome {
    Response(Response),
    Dropped(DropReason),
    Rejected(RejectReason),
}

impl Outcome {
    pub fn response(&self) -> Option<&Response> {
        match self {
            Outcome::Response(r) => Some(r),
            _ => None,
        }
    }

    pub fn is_response(&self) -> bool {
        matches!(self, Outcome::Response(_))
    }
}

/// Shared once-only outcome slot between the runtime and the handle.
#[derive(Default)]
pub(crate) struct OutcomeSlot {
    state: Mutex<Option<Outcome>>,
    cv: Condvar,
}

impl OutcomeSlot {
    /// First resolution wins; later calls are no-ops (e.g. a retry's
    /// duplicate response racing a deadline drop).
    pub(crate) fn resolve(&self, outcome: Outcome) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.is_some() {
            return false;
        }
        *st = Some(outcome);
        self.cv.notify_all();
        true
    }
}

/// One event on a streaming request's token stream.
#[derive(Clone, Debug, PartialEq)]
pub enum StreamEvent {
    /// A decoded token, in generation order.
    Token(i32),
    /// The stream is finished; this is the request's final [`Outcome`]
    /// (the same one [`RequestHandle::wait`] returns).
    Done(Outcome),
}

/// Token pipe between the collector (producer) and the client's handle
/// (consumer). Tokens queue until consumed; the terminal outcome is
/// delivered after the last token.
#[derive(Default)]
pub(crate) struct TokenStream {
    state: Mutex<StreamInner>,
    cv: Condvar,
}

#[derive(Default)]
struct StreamInner {
    tokens: std::collections::VecDeque<i32>,
    done: Option<Outcome>,
}

impl TokenStream {
    /// Producer side: append one decoded token.
    pub(crate) fn push_token(&self, tok: i32) {
        let mut st = self.state.lock().unwrap();
        if st.done.is_some() {
            return;
        }
        st.tokens.push_back(tok);
        self.cv.notify_all();
    }

    /// Producer side: terminate the stream. First call wins, mirroring
    /// [`OutcomeSlot::resolve`].
    pub(crate) fn finish(&self, outcome: Outcome) {
        let mut st = self.state.lock().unwrap();
        if st.done.is_none() {
            st.done = Some(outcome);
            self.cv.notify_all();
        }
    }

    /// Consumer side: next event, or `None` if `deadline` passes first.
    /// Buffered tokens drain before the terminal `Done`.
    fn next_event(&self, deadline: Instant) -> Option<StreamEvent> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(tok) = st.tokens.pop_front() {
                return Some(StreamEvent::Token(tok));
            }
            if let Some(o) = st.done.as_ref() {
                return Some(StreamEvent::Done(o.clone()));
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = g;
            if timeout.timed_out() && st.tokens.is_empty() && st.done.is_none() {
                return None;
            }
        }
    }
}

/// The client's side of a submitted request. See module docs.
pub struct RequestHandle {
    id: u64,
    slot: Arc<OutcomeSlot>,
    /// Attached only for streaming (multi-token) requests.
    stream: Option<Arc<TokenStream>>,
}

impl RequestHandle {
    pub(crate) fn new(id: u64, slot: Arc<OutcomeSlot>) -> Self {
        RequestHandle { id, slot, stream: None }
    }

    /// Handle for a streaming request: tokens arrive on `stream` as the
    /// decode loop produces them; the final outcome still lands in
    /// `slot` so `wait`/`try_outcome` keep working unchanged.
    pub(crate) fn new_streaming(
        id: u64,
        slot: Arc<OutcomeSlot>,
        stream: Arc<TokenStream>,
    ) -> Self {
        RequestHandle { id, slot, stream: Some(stream) }
    }

    /// Handle whose outcome is already known (admission rejection).
    pub(crate) fn resolved(id: u64, outcome: Outcome) -> Self {
        let slot = Arc::new(OutcomeSlot::default());
        slot.resolve(outcome);
        RequestHandle { id, slot, stream: None }
    }

    /// Next streaming event, or `None` if `deadline` passes first. On a
    /// non-streaming handle this degenerates to `wait_deadline` mapped
    /// into a single [`StreamEvent::Done`].
    pub fn next_event(&self, deadline: Instant) -> Option<StreamEvent> {
        match &self.stream {
            Some(s) => s.next_event(deadline),
            None => self.wait_deadline(deadline).map(StreamEvent::Done),
        }
    }

    /// Whether this handle carries a token stream.
    pub fn is_streaming(&self) -> bool {
        self.stream.is_some()
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// The outcome if already resolved (non-blocking).
    pub fn try_outcome(&self) -> Option<Outcome> {
        self.slot.state.lock().unwrap().clone()
    }

    /// Block until the request resolves.
    pub fn wait(&self) -> Outcome {
        let mut st = self.slot.state.lock().unwrap();
        loop {
            if let Some(o) = st.as_ref() {
                return o.clone();
            }
            st = self.slot.cv.wait(st).unwrap();
        }
    }

    /// Block until the request resolves or `deadline` passes.
    pub fn wait_deadline(&self, deadline: Instant) -> Option<Outcome> {
        let mut st = self.slot.state.lock().unwrap();
        loop {
            if let Some(o) = st.as_ref() {
                return Some(o.clone());
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, timeout) = self
                .slot
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = g;
            if timeout.timed_out() && st.is_none() {
                return None;
            }
        }
    }
}

/// Poisson-arrival synthetic workload: fixed-length uniform-random token
/// sequences, exponential inter-arrival gaps.
pub struct RequestGen {
    rng: Rng,
    next_id: u64,
    pub seq_len: usize,
    pub vocab: usize,
    /// Requests per second (None = as-fast-as-possible).
    pub rate: Option<f64>,
}

impl RequestGen {
    pub fn new(seed: u64, seq_len: usize, vocab: usize, rate: Option<f64>) -> Self {
        RequestGen { rng: Rng::new(seed), next_id: 0, seq_len, vocab, rate }
    }

    /// Produce the next request, returning the inter-arrival delay the
    /// caller should sleep before injecting it (0 for open-loop max
    /// rate).
    pub fn next(&mut self) -> (Request, std::time::Duration) {
        let tokens: Vec<i32> = (0..self.seq_len)
            .map(|_| self.rng.below(self.vocab as u64) as i32)
            .collect();
        let id = self.next_id;
        self.next_id += 1;
        let gap = match self.rate {
            Some(r) => std::time::Duration::from_secs_f64(self.rng.exp(r)),
            None => std::time::Duration::ZERO,
        };
        (Request::new(id, tokens), gap)
    }

    /// Generate `n` requests eagerly (benchmark setup path).
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next().0).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn ids_are_sequential_and_tokens_in_range() {
        let mut g = RequestGen::new(1, 16, 256, None);
        let reqs = g.take(50);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), 16);
            assert!(r.tokens.iter().all(|&t| (0..256).contains(&t)));
        }
    }

    #[test]
    fn poisson_gaps_have_correct_mean() {
        let mut g = RequestGen::new(2, 4, 16, Some(100.0));
        let n = 5000;
        let total: f64 = (0..n).map(|_| g.next().1.as_secs_f64()).sum();
        let mean = total / n as f64;
        assert!((mean - 0.01).abs() < 0.002, "mean gap {mean}");
    }

    #[test]
    fn open_loop_has_zero_gap() {
        let mut g = RequestGen::new(3, 4, 16, None);
        assert_eq!(g.next().1, std::time::Duration::ZERO);
    }

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<_> = RequestGen::new(7, 8, 64, None).take(10);
        let b: Vec<_> = RequestGen::new(7, 8, 64, None).take(10);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn deadline_expiry() {
        let mut r = Request::new(1, vec![0; 4]);
        assert!(!r.expired_at(r.arrival + 1e9), "no deadline, never expires");
        r.deadline = Some(r.arrival + 0.5);
        assert!(!r.expired_at(r.arrival + 0.4));
        assert!(r.expired_at(r.arrival + 0.6));
    }

    #[test]
    fn handle_resolves_once() {
        let slot = Arc::new(OutcomeSlot::default());
        let h = RequestHandle::new(7, slot.clone());
        assert!(h.try_outcome().is_none());
        assert!(slot.resolve(Outcome::Dropped(DropReason::Deadline)));
        assert!(
            !slot.resolve(Outcome::Response(Response {
                id: 7,
                latency: 0.0,
                next_token: 0
            })),
            "second resolution is a no-op"
        );
        assert_eq!(h.wait(), Outcome::Dropped(DropReason::Deadline));
        assert_eq!(h.id(), 7);
    }

    #[test]
    fn handle_wait_crosses_threads() {
        let slot = Arc::new(OutcomeSlot::default());
        let h = RequestHandle::new(1, slot.clone());
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            slot.resolve(Outcome::Response(Response {
                id: 1,
                latency: 0.02,
                next_token: 3
            }));
        });
        let got = h.wait();
        t.join().unwrap();
        assert!(got.is_response());
        assert_eq!(got.response().unwrap().next_token, 3);
    }

    #[test]
    fn handle_wait_deadline_times_out_then_resolves() {
        let slot = Arc::new(OutcomeSlot::default());
        let h = RequestHandle::new(1, slot.clone());
        assert!(h
            .wait_deadline(Instant::now() + Duration::from_millis(20))
            .is_none());
        slot.resolve(Outcome::Dropped(DropReason::Shutdown));
        assert_eq!(
            h.wait_deadline(Instant::now() + Duration::from_millis(20)),
            Some(Outcome::Dropped(DropReason::Shutdown))
        );
    }

    #[test]
    fn pre_resolved_handle() {
        let h = RequestHandle::resolved(9, Outcome::Rejected(RejectReason::QueueFull));
        assert_eq!(h.try_outcome(), Some(Outcome::Rejected(RejectReason::QueueFull)));
        assert!(!h.is_streaming());
        // Non-streaming next_event degenerates to Done(outcome).
        assert_eq!(
            h.next_event(Instant::now() + Duration::from_millis(10)),
            Some(StreamEvent::Done(Outcome::Rejected(RejectReason::QueueFull)))
        );
    }

    #[test]
    fn tenant_defaults_and_builder() {
        let r = Request::new(1, vec![0; 4]);
        assert!(r.tenant.is_default());
        assert_eq!(r.tenant.as_str(), DEFAULT_TENANT);
        let r = r.with_tenant("gold");
        assert!(!r.tenant.is_default());
        assert_eq!(r.tenant, TenantId::from("gold"));
        assert_eq!(r.tenant.to_string(), "gold");
        // Cheap clones share the same allocation.
        let t2 = r.tenant.clone();
        assert_eq!(t2, r.tenant);
    }

    #[test]
    fn max_tokens_builder_clamps() {
        let r = Request::new(1, vec![0; 4]);
        assert_eq!(r.max_tokens, 1, "default is the one-shot path");
        assert_eq!(r.clone().with_max_tokens(8).max_tokens, 8);
        assert_eq!(r.with_max_tokens(0).max_tokens, 1, "budget clamps to ≥ 1");
    }

    #[test]
    fn stream_drains_tokens_before_done() {
        let stream = Arc::new(TokenStream::default());
        let slot = Arc::new(OutcomeSlot::default());
        let h = RequestHandle::new_streaming(5, slot.clone(), stream.clone());
        assert!(h.is_streaming());
        stream.push_token(11);
        stream.push_token(22);
        let resp = Response { id: 5, latency: 0.1, next_token: 22 };
        stream.finish(Outcome::Response(resp.clone()));
        slot.resolve(Outcome::Response(resp.clone()));
        let dl = || Instant::now() + Duration::from_millis(50);
        assert_eq!(h.next_event(dl()), Some(StreamEvent::Token(11)));
        assert_eq!(h.next_event(dl()), Some(StreamEvent::Token(22)));
        assert_eq!(h.next_event(dl()), Some(StreamEvent::Done(Outcome::Response(resp.clone()))));
        // Done is sticky: further polls keep returning it.
        assert_eq!(h.next_event(dl()), Some(StreamEvent::Done(Outcome::Response(resp))));
        // The legacy surface still works on a streaming handle.
        assert!(h.wait().is_response());
    }

    #[test]
    fn stream_times_out_then_delivers_across_threads() {
        let stream = Arc::new(TokenStream::default());
        let slot = Arc::new(OutcomeSlot::default());
        let h = RequestHandle::new_streaming(6, slot, stream.clone());
        assert!(
            h.next_event(Instant::now() + Duration::from_millis(20)).is_none(),
            "empty stream times out"
        );
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            stream.push_token(7);
            stream.finish(Outcome::Dropped(DropReason::Shutdown));
        });
        assert_eq!(
            h.next_event(Instant::now() + Duration::from_secs(5)),
            Some(StreamEvent::Token(7))
        );
        t.join().unwrap();
        assert_eq!(
            h.next_event(Instant::now() + Duration::from_secs(5)),
            Some(StreamEvent::Done(Outcome::Dropped(DropReason::Shutdown)))
        );
    }

    #[test]
    fn finished_stream_ignores_late_tokens() {
        let stream = TokenStream::default();
        stream.finish(Outcome::Dropped(DropReason::Deadline));
        stream.push_token(3);
        stream.finish(Outcome::Dropped(DropReason::Failed));
        assert_eq!(
            stream.next_event(Instant::now()),
            Some(StreamEvent::Done(Outcome::Dropped(DropReason::Deadline))),
            "first finish wins; post-finish tokens are discarded"
        );
    }
}
