//! Inference requests, responses and the synthetic workload generator.

use crate::util::prng::Rng;
use crate::util::time::since_epoch;

/// One inference request: a token sequence for the model.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Arrival time (seconds since experiment epoch).
    pub arrival: f64,
}

impl Request {
    pub fn new(id: u64, tokens: Vec<i32>) -> Self {
        Request { id, tokens, arrival: since_epoch() }
    }
}

/// The serving result for one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Seconds from arrival to completion.
    pub latency: f64,
    /// Argmax token at the last position (the "answer"; enough to prove
    /// real logits flowed back).
    pub next_token: i32,
}

/// Poisson-arrival synthetic workload: fixed-length uniform-random token
/// sequences, exponential inter-arrival gaps.
pub struct RequestGen {
    rng: Rng,
    next_id: u64,
    pub seq_len: usize,
    pub vocab: usize,
    /// Requests per second (None = as-fast-as-possible).
    pub rate: Option<f64>,
}

impl RequestGen {
    pub fn new(seed: u64, seq_len: usize, vocab: usize, rate: Option<f64>) -> Self {
        RequestGen { rng: Rng::new(seed), next_id: 0, seq_len, vocab, rate }
    }

    /// Produce the next request, returning the inter-arrival delay the
    /// caller should sleep before injecting it (0 for open-loop max
    /// rate).
    pub fn next(&mut self) -> (Request, std::time::Duration) {
        let tokens: Vec<i32> = (0..self.seq_len)
            .map(|_| self.rng.below(self.vocab as u64) as i32)
            .collect();
        let id = self.next_id;
        self.next_id += 1;
        let gap = match self.rate {
            Some(r) => std::time::Duration::from_secs_f64(self.rng.exp(r)),
            None => std::time::Duration::ZERO,
        };
        (Request::new(id, tokens), gap)
    }

    /// Generate `n` requests eagerly (benchmark setup path).
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next().0).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_sequential_and_tokens_in_range() {
        let mut g = RequestGen::new(1, 16, 256, None);
        let reqs = g.take(50);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), 16);
            assert!(r.tokens.iter().all(|&t| (0..256).contains(&t)));
        }
    }

    #[test]
    fn poisson_gaps_have_correct_mean() {
        let mut g = RequestGen::new(2, 4, 16, Some(100.0));
        let n = 5000;
        let total: f64 = (0..n).map(|_| g.next().1.as_secs_f64()).sum();
        let mean = total / n as f64;
        assert!((mean - 0.01).abs() < 0.002, "mean gap {mean}");
    }

    #[test]
    fn open_loop_has_zero_gap() {
        let mut g = RequestGen::new(3, 4, 16, None);
        assert_eq!(g.next().1, std::time::Duration::ZERO);
    }

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<_> = RequestGen::new(7, 8, 64, None).take(10);
        let b: Vec<_> = RequestGen::new(7, 8, 64, None).take(10);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
        }
    }
}
