//! The autoscaler: the closed loop between live serving signals and the
//! controller's scale-out/scale-in machinery.
//!
//! Earlier revisions left `Controller::maybe_scale_out` driven only by
//! hand-fed depths; this loop closes the paper's "online scaling as
//! workloads change dynamically" claim end to end. Every
//! `policy.interval` it samples:
//!
//! * **queue depth per alive replica** — the admission queue depth over
//!   router liveness (the same signal `Leader::depth_per_replica`
//!   reports);
//! * **recent p99 latency vs. the SLO target** — from the leader's
//!   sliding window, so an old breach or an old healthy streak cannot
//!   mask the present;
//! * **recent p99 time-to-first-token vs. the TTFT target** — the
//!   decode loop's admission-side latency signal (`MW_SLO_TTFT_MS`):
//!   under continuous batching a saturated running batch shows up as
//!   queue wait before the first token long before whole-request
//!   latency moves. Decode throughput (tokens/s) rides the
//!   `serving.tokens_per_s` gauge for observability;
//! * **replica liveness** — zero alive replicas means an outage in
//!   progress; scaling decisions wait for the controller's *recovery*
//!   path instead of stacking new replicas onto a broken pipeline.
//!
//! Decisions use **hysteresis** (`high_samples` consecutive hot
//! samples to scale out, `low_samples` consecutive idle samples to
//! scale in) and a **cooldown** after every action, so a single burst
//! or a sampling blip cannot flap the topology. Scale-in is
//! **graceful**: the victim's leader-facing edges are quiesced first
//! (no new batches routed), outstanding batches drain, and only then is
//! the replica retired via `Controller::scale_in`.
//!
//! Observability: every decision lands in the controller's `Action`
//! log, the `serving.autoscale.{out,in}` counters, and structured
//! `autoscale.*` log events; per-tick signals ride the
//! `serving.autoscale.{depth_per_replica,replicas}` gauges.

use super::controller::{Action, Controller};
use super::topology::NodeId;
use crate::config::ServingConfig;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One tenant's load sample: admission depth across both batchers, the
/// recent p99 over that tenant's own completion window, and the
/// tenant's resolved request SLO (0 when the tenant has no latency
/// target, in which case it never participates in breach attribution).
#[derive(Clone, Debug, Default)]
pub struct TenantSignal {
    pub tenant: String,
    pub depth: usize,
    pub p99_ms: f64,
    pub slo_ms: f64,
}

/// Live load signals the autoscaler samples, plus the drain hooks it
/// needs for graceful scale-in. Implemented by the serving
/// [`Leader`](super::leader::Leader); test fixtures fake it.
pub trait LoadSignals: Send + Sync {
    /// Admission queue depth right now.
    fn queue_depth(&self) -> usize;
    /// Alive stage-0 replicas (router liveness).
    fn alive_replicas(&self) -> usize;
    /// Dispatched batches not yet answered.
    fn outstanding_batches(&self) -> usize;
    /// p99 latency (ms) over the recent window (0 when idle).
    fn recent_p99_ms(&self) -> f64;
    /// p99 time-to-first-token (ms) over the recent window (0 when
    /// idle or when the deployment serves no streaming traffic).
    fn recent_ttft_p99_ms(&self) -> f64 {
        0.0
    }
    /// Decoded tokens per second over the recent window (0 without
    /// streaming traffic). Observability signal, not a trigger.
    fn tokens_per_s(&self) -> f64 {
        0.0
    }
    /// Per-tenant depth/p99/SLO samples. Empty when the deployment has
    /// no tenant table (`MW_TENANTS` unset) — the per-tenant gauges and
    /// breach attribution are then skipped entirely, keeping the
    /// single-tenant metric surface unchanged.
    fn tenant_signals(&self) -> Vec<TenantSignal> {
        Vec::new()
    }
    /// Stop routing new batches to these in-edges (drain start).
    fn quiesce_edges(&self, edges: &[String]);
    /// Undo a quiesce (the retirement failed): route to these in-edges
    /// again.
    fn restore_edges(&self, edges: &[String]);
    /// Forget retired edges entirely (drain complete).
    fn release_edges(&self, edges: &[String]);
}

impl LoadSignals for super::leader::Leader {
    fn queue_depth(&self) -> usize {
        Self::queue_depth(self)
    }
    fn alive_replicas(&self) -> usize {
        Self::alive_replicas(self)
    }
    fn outstanding_batches(&self) -> usize {
        Self::outstanding_batches(self)
    }
    fn recent_p99_ms(&self) -> f64 {
        Self::recent_p99_ms(self)
    }
    fn recent_ttft_p99_ms(&self) -> f64 {
        Self::recent_ttft_p99_ms(self)
    }
    fn tokens_per_s(&self) -> f64 {
        Self::tokens_per_s(self)
    }
    fn tenant_signals(&self) -> Vec<TenantSignal> {
        Self::tenant_signals(self)
    }
    fn quiesce_edges(&self, edges: &[String]) {
        Self::quiesce_edges(self, edges)
    }
    fn restore_edges(&self, edges: &[String]) {
        Self::restore_edges(self, edges)
    }
    fn release_edges(&self, edges: &[String]) {
        Self::release_edges(self, edges)
    }
}

/// Autoscaler knobs. See module docs for the decision rules.
#[derive(Clone, Debug)]
pub struct AutoscalePolicy {
    /// Stage whose replica count the loop manages. Graceful scale-in
    /// drain (quiesce → drain → retire) applies to stages the leader
    /// feeds directly (stage 0); for deeper stages there are no
    /// leader-routed in-edges to quiesce, so retirement relies on the
    /// leader's retry path for any batch caught in flight.
    pub stage: usize,
    /// Sampling period.
    pub interval: Duration,
    /// Minimum quiet time after any action.
    pub cooldown: Duration,
    /// Queue depth per alive replica that counts as a hot sample. The
    /// controller re-checks its own `ScalingPolicy::scale_up_depth` on
    /// depth-triggered scale-outs — keep this at or above it, or the
    /// controller vetoes the decision (logged as
    /// `autoscale.out_blocked`).
    pub high_depth: f64,
    /// p99 target (ms); a recent p99 above it counts as a hot sample
    /// even with a shallow queue. 0 = latency is not a trigger.
    pub slo_p99_ms: f64,
    /// Time-to-first-token p99 target (ms) for streaming traffic; a
    /// recent TTFT p99 above it counts as a hot sample even with a
    /// shallow queue (a saturated running batch queues prefills, which
    /// shows up here first). 0 = TTFT is not a trigger.
    pub slo_ttft_ms: f64,
    /// Consecutive hot samples before scale-out.
    pub high_samples: u32,
    /// Consecutive idle samples before scale-in.
    pub low_samples: u32,
    /// Never scale in below this many replicas.
    pub min_replicas: usize,
    /// How long a graceful drain may wait for outstanding batches.
    pub drain_timeout: Duration,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            stage: 0,
            interval: Duration::from_millis(100),
            cooldown: Duration::from_secs(2),
            high_depth: 16.0,
            slo_p99_ms: 0.0,
            slo_ttft_ms: 0.0,
            high_samples: 3,
            low_samples: 20,
            min_replicas: 1,
            drain_timeout: Duration::from_secs(5),
        }
    }
}

impl AutoscalePolicy {
    /// Policy derived from the serving config's knobs (SLO target,
    /// scale-out depth, sampling interval, cooldown).
    pub fn from_config(cfg: &ServingConfig) -> Self {
        AutoscalePolicy {
            interval: Duration::from_millis(cfg.autoscale_interval_ms.max(1)),
            cooldown: Duration::from_millis(cfg.autoscale_cooldown_ms),
            high_depth: cfg.scale_up_queue_depth as f64,
            slo_p99_ms: cfg.slo_ms as f64,
            slo_ttft_ms: cfg.slo_ttft_ms as f64,
            ..Default::default()
        }
    }
}

/// Running autoscaler loop; stops (and joins) on [`stop`](Self::stop)
/// or drop.
pub struct AutoscalerHandle {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl AutoscalerHandle {
    fn halt(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }

    pub fn stop(mut self) {
        self.halt();
    }
}

impl Drop for AutoscalerHandle {
    fn drop(&mut self) {
        self.halt();
    }
}

/// See module docs.
pub struct Autoscaler {
    controller: Arc<Controller>,
    signals: Arc<dyn LoadSignals>,
    policy: AutoscalePolicy,
    stop: Arc<AtomicBool>,
    breach_streak: u32,
    idle_streak: u32,
    last_action: Option<Instant>,
}

impl Autoscaler {
    pub fn new(
        controller: Arc<Controller>,
        signals: Arc<dyn LoadSignals>,
        policy: AutoscalePolicy,
    ) -> Autoscaler {
        Autoscaler {
            controller,
            signals,
            policy,
            stop: Arc::new(AtomicBool::new(false)),
            breach_streak: 0,
            idle_streak: 0,
            last_action: None,
        }
    }

    /// Spawn the sampling loop on its own thread.
    pub fn start(mut self) -> AutoscalerHandle {
        let stop = self.stop.clone();
        let interval = self.policy.interval;
        let thread = std::thread::Builder::new()
            .name("autoscaler".into())
            .spawn(move || {
                while !self.stop.load(Ordering::Relaxed) {
                    std::thread::sleep(interval);
                    if self.stop.load(Ordering::Relaxed) {
                        break;
                    }
                    self.tick();
                }
            })
            .expect("spawn autoscaler");
        AutoscalerHandle { stop, thread: Some(thread) }
    }

    /// One sampling step: read the live signals, update the hysteresis
    /// streaks, maybe act. Public so embedders (and tests) can drive
    /// the loop themselves.
    pub fn tick(&mut self) -> Option<Action> {
        let alive = self.signals.alive_replicas();
        let g = crate::metrics::global();
        g.gauge("serving.autoscale.replicas").set(alive as i64);
        if alive == 0 {
            // Outage: recovery (not scaling) must restore service first.
            self.breach_streak = 0;
            self.idle_streak = 0;
            return None;
        }
        let depth = self.signals.queue_depth() as f64 / alive as f64;
        let p99 = self.signals.recent_p99_ms();
        let ttft = self.signals.recent_ttft_p99_ms();
        g.gauge("serving.autoscale.depth_per_replica").set(depth as i64);
        g.gauge("serving.recent_p99_ms").set(p99 as i64);
        g.gauge("serving.recent_ttft_p99_ms").set(ttft as i64);
        g.gauge("serving.tokens_per_s").set(self.signals.tokens_per_s() as i64);
        // Per-tenant sampling: publish each tenant's depth and p99, and
        // attribute any SLO breach to the tenant furthest over its own
        // target (largest p99/SLO ratio). A tenant-level breach counts
        // as a hot sample even when the aggregate p99 looks healthy — a
        // gold tenant drowning behind free-tier traffic is exactly the
        // signal the aggregate hides.
        let mut breach_tenant: Option<(String, f64)> = None;
        for ts in self.signals.tenant_signals() {
            g.gauge(&format!("serving.autoscale.tenant_depth.{}", ts.tenant))
                .set(ts.depth as i64);
            g.gauge(&format!("serving.recent_p99_ms.tenant.{}", ts.tenant))
                .set(ts.p99_ms as i64);
            if ts.slo_ms > 0.0 && ts.p99_ms > ts.slo_ms {
                let ratio = ts.p99_ms / ts.slo_ms;
                if breach_tenant.as_ref().map_or(true, |(_, worst)| ratio > *worst) {
                    breach_tenant = Some((ts.tenant, ratio));
                }
            }
        }
        let slo_hot = (self.policy.slo_p99_ms > 0.0 && p99 > self.policy.slo_p99_ms)
            || (self.policy.slo_ttft_ms > 0.0 && ttft > self.policy.slo_ttft_ms)
            || breach_tenant.is_some();
        let hot = depth >= self.policy.high_depth || slo_hot;
        let idle = self.signals.queue_depth() == 0
            && self.signals.outstanding_batches() == 0
            && !slo_hot;
        if hot {
            self.breach_streak += 1;
            self.idle_streak = 0;
        } else if idle {
            self.idle_streak += 1;
            self.breach_streak = 0;
        } else {
            self.breach_streak = 0;
            self.idle_streak = 0;
        }
        let cooled = match self.last_action {
            None => true,
            Some(t) => t.elapsed() >= self.policy.cooldown,
        };
        if hot && self.breach_streak >= self.policy.high_samples {
            // The cooldown exists because a cold spawn is expensive and
            // slow to show up in the signals. With a warm spare standing
            // by (`MW_SPARES`), scale-out is promote-then-backfill —
            // near-free — so pool headroom overrides the cooldown.
            if cooled || self.controller.spare_headroom() > 0 {
                return self.try_scale_out(depth, p99, slo_hot, breach_tenant);
            }
            return None;
        }
        if !cooled {
            return None;
        }
        if idle && self.idle_streak >= self.policy.low_samples {
            return self.try_scale_in();
        }
        None
    }

    /// Drive `Controller::maybe_scale_out` with the measured signal. An
    /// SLO breach overrides a shallow queue: the latency target *is*
    /// the demand signal then, so the depth check is forced open. When
    /// a per-tenant breach drove the decision, `breach_tenant` names
    /// the worst offender so the action log and the
    /// `serving.autoscale.tenant_breach.<tenant>` counter attribute the
    /// scale-out instead of blaming "the workload".
    fn try_scale_out(
        &mut self,
        depth: f64,
        p99: f64,
        slo_hot: bool,
        breach_tenant: Option<(String, f64)>,
    ) -> Option<Action> {
        let signal = if slo_hot { f64::INFINITY } else { depth };
        match self.controller.maybe_scale_out(self.policy.stage, signal) {
            Ok(Some(action)) => {
                let g = crate::metrics::global();
                g.counter("serving.autoscale.out").inc();
                let stage = self.policy.stage.to_string();
                let depth_s = format!("{depth:.1}");
                let p99_s = format!("{p99:.1}");
                let trigger = if slo_hot { "slo" } else { "depth" };
                let mut fields: Vec<(&str, &str)> = vec![
                    ("stage", stage.as_str()),
                    ("depth_per_replica", depth_s.as_str()),
                    ("p99_ms", p99_s.as_str()),
                    ("trigger", trigger),
                ];
                if let Some((tenant, _ratio)) = &breach_tenant {
                    g.counter(&format!("serving.autoscale.tenant_breach.{tenant}")).inc();
                    fields.push(("tenant", tenant.as_str()));
                }
                crate::metrics::log_event("autoscale.out", &fields);
                self.last_action = Some(Instant::now());
                self.breach_streak = 0;
                Some(action)
            }
            Ok(None) => {
                // The controller vetoed: replica ceiling reached, or its
                // own scale_up_depth gate is stricter than high_depth.
                // Log it — a silent veto looks like a dead autoscaler —
                // and take the cooldown so a sustained ceiling doesn't
                // re-log every tick.
                crate::metrics::log_event(
                    "autoscale.out_blocked",
                    &[
                        ("stage", self.policy.stage.to_string().as_str()),
                        ("depth_per_replica", format!("{depth:.1}").as_str()),
                    ],
                );
                self.last_action = Some(Instant::now());
                self.breach_streak = 0;
                None
            }
            Err(e) => {
                crate::metrics::log_event(
                    "autoscale.out_failed",
                    &[("error", e.to_string().as_str())],
                );
                // Backoff: without the cooldown a persistent failure
                // would be retried on every tick.
                self.last_action = Some(Instant::now());
                self.breach_streak = 0;
                None
            }
        }
    }

    /// Graceful scale-in: quiesce the newest replica's leader-facing
    /// edges, wait for outstanding batches to drain, then retire it.
    fn try_scale_in(&mut self) -> Option<Action> {
        let stage = self.policy.stage;
        let topo = self.controller.topology();
        let live = topo.live_replicas(stage);
        if live.len() <= self.policy.min_replicas {
            self.idle_streak = 0;
            return None;
        }
        let victim_replica = *live.last()?;
        let victim = NodeId::worker(stage, victim_replica);
        let worlds = topo.worlds_of(victim);
        // Worlds the leader shares with the victim: the `in-*` edges it
        // routes batches over (leader is rank 0) and, for a last-stage
        // victim, the `out-*` edge it collects on.
        let leader_worlds: Vec<String> = worlds
            .iter()
            .filter(|w| w.members.contains(&NodeId::Leader))
            .map(|w| w.name.clone())
            .collect();
        let in_edges: Vec<String> = worlds
            .iter()
            .filter(|w| w.members.first() == Some(&NodeId::Leader))
            .map(|w| w.name.clone())
            .collect();
        self.signals.quiesce_edges(&in_edges);
        let deadline = Instant::now() + self.policy.drain_timeout;
        while self.signals.outstanding_batches() > 0
            && Instant::now() < deadline
            && !self.stop.load(Ordering::Relaxed)
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        if self.stop.load(Ordering::Relaxed) {
            // Shutting down mid-drain: abort the retirement instead of
            // mutating topology under a dying cluster.
            self.signals.restore_edges(&in_edges);
            return None;
        }
        if self.signals.outstanding_batches() > 0 {
            // Leftovers re-route via the leader's retry path; note it.
            crate::metrics::log_event(
                "autoscale.drain_timeout",
                &[("node", victim.to_string().as_str())],
            );
        }
        match self.controller.scale_in(victim) {
            Ok(Some(action)) => {
                self.signals.release_edges(&leader_worlds);
                crate::metrics::global().counter("serving.autoscale.in").inc();
                crate::metrics::log_event(
                    "autoscale.in",
                    &[("node", victim.to_string().as_str())],
                );
                self.last_action = Some(Instant::now());
                self.idle_streak = 0;
                Some(action)
            }
            Ok(None) => {
                // Replica already gone (raced a failure); forget its
                // edges either way.
                self.signals.release_edges(&leader_worlds);
                self.idle_streak = 0;
                None
            }
            Err(e) => {
                // Retirement failed: the replica is still alive and in
                // the topology — give it its traffic back instead of
                // stranding capacity, and take the cooldown so the
                // quiesce/restore cycle can't churn every tick.
                self.signals.restore_edges(&in_edges);
                crate::metrics::log_event(
                    "autoscale.in_failed",
                    &[("error", e.to_string().as_str())],
                );
                self.last_action = Some(Instant::now());
                self.idle_streak = 0;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::controller::{ScalingPolicy, Spawner};
    use crate::serving::topology::{Topology, WorldDef};
    use std::sync::atomic::AtomicUsize;
    use std::sync::Mutex;

    struct NullSpawner;
    impl Spawner for NullSpawner {
        fn spawn(&self, _node: NodeId, _worlds: Vec<WorldDef>) -> anyhow::Result<()> {
            Ok(())
        }
    }

    #[derive(Default)]
    struct FakeSignals {
        depth: AtomicUsize,
        alive: AtomicUsize,
        outstanding: AtomicUsize,
        p99: Mutex<f64>,
        ttft: Mutex<f64>,
        tenants: Mutex<Vec<TenantSignal>>,
        quiesced: Mutex<Vec<String>>,
        restored: Mutex<Vec<String>>,
        released: Mutex<Vec<String>>,
    }

    impl LoadSignals for FakeSignals {
        fn queue_depth(&self) -> usize {
            self.depth.load(Ordering::Relaxed)
        }
        fn alive_replicas(&self) -> usize {
            self.alive.load(Ordering::Relaxed)
        }
        fn outstanding_batches(&self) -> usize {
            self.outstanding.load(Ordering::Relaxed)
        }
        fn recent_p99_ms(&self) -> f64 {
            *self.p99.lock().unwrap()
        }
        fn recent_ttft_p99_ms(&self) -> f64 {
            *self.ttft.lock().unwrap()
        }
        fn tenant_signals(&self) -> Vec<TenantSignal> {
            self.tenants.lock().unwrap().clone()
        }
        fn quiesce_edges(&self, edges: &[String]) {
            self.quiesced.lock().unwrap().extend(edges.iter().cloned());
        }
        fn restore_edges(&self, edges: &[String]) {
            self.restored.lock().unwrap().extend(edges.iter().cloned());
        }
        fn release_edges(&self, edges: &[String]) {
            self.released.lock().unwrap().extend(edges.iter().cloned());
        }
    }

    fn setup(
        replicas: &[usize],
        policy: AutoscalePolicy,
        scaling: ScalingPolicy,
    ) -> (Autoscaler, Arc<Controller>, Arc<FakeSignals>) {
        let topo = Topology::pipeline("as", replicas, 39_000);
        let controller = Arc::new(Controller::new(
            topo,
            scaling,
            Box::new(NullSpawner),
            |_def| Ok(()),
        ));
        let signals = Arc::new(FakeSignals::default());
        signals.alive.store(replicas[0], Ordering::Relaxed);
        let a = Autoscaler::new(controller.clone(), signals.clone(), policy);
        (a, controller, signals)
    }

    fn hot_policy() -> AutoscalePolicy {
        AutoscalePolicy {
            high_depth: 8.0,
            high_samples: 3,
            low_samples: 2,
            cooldown: Duration::from_secs(60),
            min_replicas: 1,
            drain_timeout: Duration::from_millis(10),
            ..Default::default()
        }
    }

    #[test]
    fn scale_out_needs_consecutive_hot_samples() {
        let (mut a, c, s) = setup(
            &[1],
            hot_policy(),
            ScalingPolicy { scale_up_depth: 8.0, max_replicas: 2, recover: false },
        );
        s.depth.store(100, Ordering::Relaxed);
        assert!(a.tick().is_none(), "1st hot sample: hysteresis holds");
        // A cool sample resets the streak.
        s.depth.store(0, Ordering::Relaxed);
        s.outstanding.store(1, Ordering::Relaxed); // not idle either
        assert!(a.tick().is_none());
        s.depth.store(100, Ordering::Relaxed);
        assert!(a.tick().is_none());
        assert!(a.tick().is_none());
        let action = a.tick().expect("3rd consecutive hot sample scales out");
        assert!(matches!(action, Action::ScaledOut { stage: 0, .. }));
        assert_eq!(c.topology().replicas[0], 2);
        // Cooldown blocks an immediate second scale-out.
        assert!(a.tick().is_none());
        assert!(a.tick().is_none());
        assert!(a.tick().is_none());
        assert_eq!(c.topology().replicas[0], 2);
    }

    #[test]
    fn slo_breach_scales_out_with_shallow_queue() {
        let (mut a, c, s) = setup(
            &[1],
            AutoscalePolicy { slo_p99_ms: 50.0, high_samples: 2, ..hot_policy() },
            ScalingPolicy { scale_up_depth: 1e9, max_replicas: 2, recover: false },
        );
        s.depth.store(1, Ordering::Relaxed);
        *s.p99.lock().unwrap() = 200.0;
        assert!(a.tick().is_none());
        let action = a.tick().expect("latency breach forces the depth check open");
        assert!(matches!(action, Action::ScaledOut { stage: 0, .. }));
        assert_eq!(c.topology().replicas[0], 2);
    }

    #[test]
    fn ttft_breach_scales_out_with_shallow_queue() {
        // Streaming saturation: whole-request p99 stays healthy (tokens
        // are flowing), but prefills queue behind the running batch and
        // TTFT breaches. That alone must trigger scale-out.
        let (mut a, c, s) = setup(
            &[1],
            AutoscalePolicy { slo_ttft_ms: 25.0, high_samples: 2, ..hot_policy() },
            ScalingPolicy { scale_up_depth: 1e9, max_replicas: 2, recover: false },
        );
        s.depth.store(1, Ordering::Relaxed);
        *s.p99.lock().unwrap() = 1.0; // well under any whole-request SLO
        *s.ttft.lock().unwrap() = 80.0;
        assert!(a.tick().is_none());
        let action = a.tick().expect("TTFT breach forces the depth check open");
        assert!(matches!(action, Action::ScaledOut { stage: 0, .. }));
        assert_eq!(c.topology().replicas[0], 2);
    }

    #[test]
    fn tenant_slo_breach_scales_out_and_names_the_tenant() {
        // Aggregate p99 healthy, queue shallow — but one tenant is 2.4×
        // over its own SLO. That alone must count as a hot sample, and
        // the attribution must blame the tenant furthest over target
        // (gold at 120/50 = 2.4×, not free at 600/500 = 1.2×).
        let (mut a, c, s) = setup(
            &[1],
            AutoscalePolicy { high_samples: 2, ..hot_policy() },
            ScalingPolicy { scale_up_depth: 1e9, max_replicas: 2, recover: false },
        );
        s.depth.store(1, Ordering::Relaxed);
        *s.p99.lock().unwrap() = 1.0;
        *s.tenants.lock().unwrap() = vec![
            TenantSignal { tenant: "free".into(), depth: 7, p99_ms: 600.0, slo_ms: 500.0 },
            TenantSignal { tenant: "gold".into(), depth: 2, p99_ms: 120.0, slo_ms: 50.0 },
        ];
        assert!(a.tick().is_none(), "hysteresis holds on the 1st tenant breach");
        let action = a.tick().expect("tenant-level breach forces scale-out");
        assert!(matches!(action, Action::ScaledOut { stage: 0, .. }));
        assert_eq!(c.topology().replicas[0], 2);
        let g = crate::metrics::global();
        assert!(
            g.counter("serving.autoscale.tenant_breach.gold").get() >= 1,
            "breach attributed to the worst-ratio tenant"
        );
        assert_eq!(g.counter("serving.autoscale.tenant_breach.free").get(), 0);
        assert_eq!(g.gauge("serving.autoscale.tenant_depth.gold").get(), 2);
        assert_eq!(g.gauge("serving.recent_p99_ms.tenant.free").get(), 600);
    }

    #[test]
    fn no_scaling_during_outage() {
        let (mut a, c, s) = setup(
            &[1],
            AutoscalePolicy { high_samples: 1, ..hot_policy() },
            ScalingPolicy { scale_up_depth: 1.0, max_replicas: 4, recover: false },
        );
        s.depth.store(1_000, Ordering::Relaxed);
        s.alive.store(0, Ordering::Relaxed);
        for _ in 0..5 {
            assert!(a.tick().is_none(), "no alive replicas: recovery first");
        }
        assert_eq!(c.topology().replicas[0], 1);
    }

    #[test]
    fn idle_scales_in_gracefully_and_respects_min_replicas() {
        let (mut a, c, s) = setup(
            &[2],
            AutoscalePolicy { low_samples: 2, cooldown: Duration::ZERO, ..hot_policy() },
            ScalingPolicy::default(),
        );
        s.alive.store(2, Ordering::Relaxed);
        let victim = NodeId::worker(0, 1);
        let topo = c.topology();
        let victim_worlds = topo.worlds_of(victim);
        let in_edges: Vec<String> = victim_worlds
            .iter()
            .filter(|w| w.members.first() == Some(&NodeId::Leader))
            .map(|w| w.name.clone())
            .collect();
        let leader_worlds: Vec<String> =
            victim_worlds.iter().map(|w| w.name.clone()).collect();
        assert!(!in_edges.is_empty() && in_edges.len() < leader_worlds.len());
        assert!(a.tick().is_none(), "1st idle sample");
        let action = a.tick().expect("2nd idle sample scales in");
        assert_eq!(action, Action::ScaledIn { node: victim });
        // Drain protocol: quiesce the routed in-edges before retiring,
        // release every leader-facing world after; no rollback needed.
        assert_eq!(*s.quiesced.lock().unwrap(), in_edges);
        assert_eq!(*s.released.lock().unwrap(), leader_worlds);
        assert!(s.restored.lock().unwrap().is_empty());
        assert!(c.topology().worlds_of(victim).is_empty());
        // Down to min_replicas: idle forever, never scale in further.
        s.alive.store(1, Ordering::Relaxed);
        for _ in 0..5 {
            assert!(a.tick().is_none());
        }
        assert_eq!(c.topology().live_replicas(0), vec![0]);
    }

    // (The drain-wait and drain-timeout paths are covered end to end by
    // tests/serving_autoscale.rs, where real in-flight batches drain.)
}
