//! Blocking store client. One TCP connection, requests serialized under
//! a mutex so a client handle can be shared across threads (the watchdog
//! thread and the communicator share one).

use super::protocol::{read_response, write_request, Op, Status};
use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::sync::Mutex;
use std::time::{Duration, Instant};

struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// Client handle to a [`super::StoreServer`].
pub struct StoreClient {
    conn: Mutex<Conn>,
    addr: SocketAddr,
}

impl StoreClient {
    /// Connect, retrying until `timeout` (rendezvous races: clients often
    /// start before the leader's server is up).
    pub fn connect(addr: SocketAddr, timeout: Duration) -> anyhow::Result<Self> {
        let deadline = Instant::now() + timeout;
        loop {
            match TcpStream::connect_timeout(&addr, Duration::from_millis(500)) {
                Ok(stream) => {
                    stream.set_nodelay(true)?;
                    let writer = stream.try_clone()?;
                    return Ok(StoreClient {
                        conn: Mutex::new(Conn { reader: BufReader::new(stream), writer }),
                        addr,
                    });
                }
                Err(e) => {
                    if Instant::now() >= deadline {
                        anyhow::bail!("store connect to {addr} timed out: {e}");
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    fn call(&self, op: Op, key: &str, val: &[u8]) -> anyhow::Result<(Status, Vec<u8>)> {
        let mut conn = self.conn.lock().unwrap();
        write_request(&mut conn.writer, op, key, val)?;
        read_response(&mut conn.reader)
    }

    /// Insert or overwrite.
    pub fn set(&self, key: &str, val: &[u8]) -> anyhow::Result<()> {
        match self.call(Op::Set, key, val)? {
            (Status::Ok, _) => Ok(()),
            (s, v) => anyhow::bail!("set failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Fetch; `None` if absent.
    pub fn get(&self, key: &str) -> anyhow::Result<Option<Vec<u8>>> {
        match self.call(Op::Get, key, &[])? {
            (Status::Ok, v) => Ok(Some(v)),
            (Status::NotFound, _) => Ok(None),
            (s, v) => anyhow::bail!("get failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Atomic add; returns the new value. Keys created on first add.
    pub fn add(&self, key: &str, delta: i64) -> anyhow::Result<i64> {
        match self.call(Op::Add, key, &delta.to_le_bytes())? {
            (Status::Ok, v) => Ok(String::from_utf8(v)?.parse()?),
            (s, v) => anyhow::bail!("add failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Block until `key` exists (or timeout) and return its value.
    pub fn wait(&self, key: &str, timeout: Duration) -> anyhow::Result<Vec<u8>> {
        let ms = timeout.as_millis() as u64;
        match self.call(Op::Wait, key, &ms.to_le_bytes())? {
            (Status::Ok, v) => Ok(v),
            (Status::Timeout, _) => anyhow::bail!("wait({key}) timeout after {ms} ms"),
            (s, v) => anyhow::bail!("wait failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Delete; returns whether the key existed.
    pub fn delete(&self, key: &str) -> anyhow::Result<bool> {
        match self.call(Op::Delete, key, &[])? {
            (Status::Ok, _) => Ok(true),
            (Status::NotFound, _) => Ok(false),
            (s, v) => anyhow::bail!("delete failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Compare-and-set. Returns the value now stored under `key`
    /// (i.e. `new` on success, the conflicting current value otherwise).
    /// PyTorch quirk preserved: empty `old` + missing key ⇒ insert.
    pub fn compare_set(&self, key: &str, old: &[u8], new: &[u8]) -> anyhow::Result<Vec<u8>> {
        let mut val = Vec::with_capacity(4 + old.len() + new.len());
        val.extend_from_slice(&(old.len() as u32).to_le_bytes());
        val.extend_from_slice(old);
        val.extend_from_slice(new);
        match self.call(Op::CompareSet, key, &val)? {
            (Status::Ok, v) => Ok(v),
            (s, v) => anyhow::bail!("compare_set failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// All keys with the given prefix.
    pub fn keys(&self, prefix: &str) -> anyhow::Result<Vec<String>> {
        match self.call(Op::Keys, prefix, &[])? {
            (Status::Ok, mut v) => {
                let mut out = Vec::new();
                let mut rest = v.as_mut_slice();
                while rest.len() >= 4 {
                    let len = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
                    anyhow::ensure!(rest.len() >= 4 + len, "short KEYS frame");
                    out.push(String::from_utf8(rest[4..4 + len].to_vec())?);
                    rest = &mut rest[4 + len..];
                }
                Ok(out)
            }
            (s, v) => anyhow::bail!("keys failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Total number of keys.
    pub fn num_keys(&self) -> anyhow::Result<u64> {
        match self.call(Op::NumKeys, "", &[])? {
            (Status::Ok, v) => {
                anyhow::ensure!(v.len() == 8, "short NUM_KEYS frame");
                Ok(u64::from_le_bytes(v.try_into().unwrap()))
            }
            (s, v) => anyhow::bail!("num_keys failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Liveness probe.
    pub fn ping(&self) -> anyhow::Result<()> {
        match self.call(Op::Ping, "", &[])? {
            (Status::Ok, _) => Ok(()),
            (s, _) => anyhow::bail!("ping failed: {s:?}"),
        }
    }
}
