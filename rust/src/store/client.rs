//! Pipelined store client over a process-global connection pool.
//!
//! All `StoreClient` handles pointing at the same server address share
//! **one** TCP connection: a mutexed writer pushes correlation-id-
//! stamped requests, a single demux reader thread routes responses back
//! to per-call channels by id. Requests from many threads interleave
//! freely — a parked `WAIT` never head-of-line-blocks a heartbeat `SET`
//! on the same socket, because the server answers out of order and the
//! reader demuxes. Concurrent world inits therefore share sockets
//! instead of minting `O(worlds × members)` connections.
//!
//! Failure domains stay per-server: the pool is keyed by address and
//! each world runs its own store, so one dying store only poisons its
//! own pooled connection. When the reader hits EOF/error it marks the
//! connection dead, fails every in-flight call, and evicts itself from
//! the pool — the next `connect` dials fresh. The watchdog's "store
//! unreachable ⇒ leader death" signal is preserved: severed server
//! sockets surface as errors on every sharing client within one demux
//! turn.
//!
//! Every call counts into `store.client.ops` (the round-trip budget
//! regression tests assert on deltas) and each dial into
//! `store.client.conns_opened`. Outgoing requests pass the store
//! fault-injection point (`edge=store:*->*` — see
//! [`crate::mwccl::transport::fault`]): delays sleep, drops pause one
//! RTO then transmit, wedges hold the request until healed or the op
//! deadline (`MW_STORE_OP_TIMEOUT_MS`, default 10 s) expires.

use super::protocol::{
    decode_maybe_values, decode_values, encode_keys, encode_pairs, encode_wait_many,
    read_response, write_request, Op, Status, MAX_KEY, MAX_VAL,
};
use crate::metrics;
use crate::mwccl::transport::fault::{store_channel_action, store_channel_wedged, StoreAction};
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Default per-op response deadline (overridable via
/// `MW_STORE_OP_TIMEOUT_MS`). Far above healthy control-plane
/// latencies; hit only when the server is wedged or gone.
static OP_TIMEOUT: Lazy<Duration> = Lazy::new(|| {
    let ms = std::env::var("MW_STORE_OP_TIMEOUT_MS")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(10_000);
    Duration::from_millis(ms)
});

/// Extra slack on top of a WAIT's own timeout before the client gives
/// up on the response (covers scheduling + timer-thread latency).
const WAIT_SLACK: Duration = Duration::from_millis(2_000);

/// One pooled connection per server address, shared process-wide.
static POOL: Lazy<Mutex<HashMap<SocketAddr, Arc<PooledConn>>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

struct PooledConn {
    addr: SocketAddr,
    writer: Mutex<TcpStream>,
    pending: Mutex<HashMap<u64, mpsc::Sender<(Status, Vec<u8>)>>>,
    next_id: AtomicU64,
    dead: AtomicBool,
}

impl PooledConn {
    /// Pool hit (live conn) or a fresh dial with exponential backoff —
    /// rendezvous races mean clients often start before the leader's
    /// server is up, so refusals retry until `timeout`.
    fn get_or_dial(addr: SocketAddr, timeout: Duration) -> anyhow::Result<Arc<PooledConn>> {
        if let Some(c) = POOL.lock().unwrap().get(&addr) {
            if !c.dead.load(Ordering::Acquire) {
                return Ok(c.clone());
            }
        }
        let deadline = Instant::now() + timeout;
        let mut backoff = Duration::from_millis(1);
        loop {
            match TcpStream::connect_timeout(&addr, Duration::from_millis(500)) {
                Ok(stream) => {
                    stream.set_nodelay(true)?;
                    let writer = stream.try_clone()?;
                    let conn = Arc::new(PooledConn {
                        addr,
                        writer: Mutex::new(writer),
                        pending: Mutex::new(HashMap::new()),
                        next_id: AtomicU64::new(1),
                        dead: AtomicBool::new(false),
                    });
                    // Someone may have won the dial race while we were
                    // connecting: keep the pool's live conn, drop ours.
                    let mut pool = POOL.lock().unwrap();
                    if let Some(existing) = pool.get(&addr) {
                        if !existing.dead.load(Ordering::Acquire) {
                            return Ok(existing.clone());
                        }
                    }
                    pool.insert(addr, conn.clone());
                    drop(pool);
                    metrics::global().counter("store.client.conns_opened").inc();
                    let rconn = conn.clone();
                    std::thread::Builder::new()
                        .name(format!("store-demux-{}", addr.port()))
                        .spawn(move || reader_loop(rconn, stream))?;
                    return Ok(conn);
                }
                Err(e) => {
                    if Instant::now() >= deadline {
                        anyhow::bail!("store connect to {addr} timed out: {e}");
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(64));
                }
            }
        }
    }

    /// Fail every in-flight call and evict this conn from the pool
    /// (unless a replacement already took the slot).
    fn mark_dead(&self) {
        self.dead.store(true, Ordering::Release);
        // Dropping the senders disconnects every waiting receiver.
        self.pending.lock().unwrap().clear();
        let mut pool = POOL.lock().unwrap();
        if let Some(cur) = pool.get(&self.addr) {
            if std::ptr::eq(Arc::as_ptr(cur), self) {
                pool.remove(&self.addr);
            }
        }
    }

    /// One pipelined round trip. `deadline` bounds the whole call,
    /// including any fault-injected wedge time.
    fn call(
        &self,
        op: Op,
        key: &str,
        val: &[u8],
        deadline: Duration,
    ) -> anyhow::Result<(Status, Vec<u8>)> {
        anyhow::ensure!(key.len() <= MAX_KEY, "store key too large: {}", key.len());
        anyhow::ensure!(val.len() <= MAX_VAL, "store value too large: {}", val.len());
        if self.dead.load(Ordering::Acquire) {
            anyhow::bail!("store connection to {} lost", self.addr);
        }
        metrics::global().counter("store.client.ops").inc();
        let hard_deadline = Instant::now() + deadline;
        // Fault point: applied per request, before the shared writer is
        // touched, so an injected sleep never blocks other callers.
        match store_channel_action(key.len() + val.len()) {
            StoreAction::Forward => {}
            StoreAction::Sleep(d) | StoreAction::Retransmit(d) => std::thread::sleep(d),
            StoreAction::Wedge => loop {
                if !store_channel_wedged() {
                    break;
                }
                if Instant::now() >= hard_deadline {
                    anyhow::bail!("store op {op:?} to {} timed out (wedged)", self.addr);
                }
                std::thread::sleep(Duration::from_millis(5));
            },
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        self.pending.lock().unwrap().insert(id, tx);
        {
            let mut w = self.writer.lock().unwrap();
            if let Err(e) = write_request(&mut *w, id, op, key, val) {
                drop(w);
                self.pending.lock().unwrap().remove(&id);
                self.mark_dead();
                anyhow::bail!("store send to {} failed: {e}", self.addr);
            }
        }
        let left = hard_deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(left) {
            Ok(resp) => Ok(resp),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                self.pending.lock().unwrap().remove(&id);
                anyhow::bail!("store op {op:?} to {} timed out", self.addr)
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                anyhow::bail!("store connection to {} lost", self.addr)
            }
        }
    }
}

/// Demux loop: route responses to callers by correlation id; on any
/// read error declare the connection dead (the server severing sockets
/// on drop is the watchdog's leader-death signal).
fn reader_loop(conn: Arc<PooledConn>, stream: TcpStream) {
    let mut reader = BufReader::new(stream);
    loop {
        match read_response(&mut reader) {
            Ok((id, status, val)) => {
                let tx = conn.pending.lock().unwrap().remove(&id);
                if let Some(tx) = tx {
                    let _ = tx.send((status, val));
                }
                // No registered caller: the caller gave up (timeout) —
                // drop the response.
            }
            Err(_) => {
                conn.mark_dead();
                return;
            }
        }
    }
}

/// Client handle to a [`super::StoreServer`]. Cheap to clone-by-
/// reconnect: handles to the same address share one pooled connection.
pub struct StoreClient {
    conn: Arc<PooledConn>,
    addr: SocketAddr,
}

impl StoreClient {
    /// Connect (or join the pooled connection), retrying dials until
    /// `timeout`.
    pub fn connect(addr: SocketAddr, timeout: Duration) -> anyhow::Result<Self> {
        let conn = PooledConn::get_or_dial(addr, timeout)?;
        Ok(StoreClient { conn, addr })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Do two handles ride the same pooled connection? (test hook)
    #[cfg(test)]
    pub(crate) fn shares_conn_with(&self, other: &StoreClient) -> bool {
        Arc::ptr_eq(&self.conn, &other.conn)
    }

    fn call(&self, op: Op, key: &str, val: &[u8]) -> anyhow::Result<(Status, Vec<u8>)> {
        self.conn.call(op, key, val, *OP_TIMEOUT)
    }

    /// Insert or overwrite.
    pub fn set(&self, key: &str, val: &[u8]) -> anyhow::Result<()> {
        match self.call(Op::Set, key, val)? {
            (Status::Ok, _) => Ok(()),
            (s, v) => anyhow::bail!("set failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Fetch; `None` if absent.
    pub fn get(&self, key: &str) -> anyhow::Result<Option<Vec<u8>>> {
        match self.call(Op::Get, key, &[])? {
            (Status::Ok, v) => Ok(Some(v)),
            (Status::NotFound, _) => Ok(None),
            (s, v) => anyhow::bail!("get failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Atomic add; returns the new value. Keys created on first add.
    pub fn add(&self, key: &str, delta: i64) -> anyhow::Result<i64> {
        match self.call(Op::Add, key, &delta.to_le_bytes())? {
            (Status::Ok, v) => Ok(String::from_utf8(v)?.parse()?),
            (s, v) => anyhow::bail!("add failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Block until `key` exists (or timeout) and return its value. The
    /// wait parks server-side (no polling); other requests keep flowing
    /// on the shared connection meanwhile.
    pub fn wait(&self, key: &str, timeout: Duration) -> anyhow::Result<Vec<u8>> {
        let ms = timeout.as_millis().min(u64::MAX as u128) as u64;
        let deadline = timeout.saturating_add(WAIT_SLACK);
        match self.conn.call(Op::Wait, key, &ms.to_le_bytes(), deadline)? {
            (Status::Ok, v) => Ok(v),
            (Status::Timeout, _) => anyhow::bail!("wait({key}) timeout after {ms} ms"),
            (s, v) => anyhow::bail!("wait failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Block until **all** `keys` exist (or timeout); returns their
    /// values in request order. One round trip regardless of key count
    /// — the O(1) primitive rendezvous address exchange rides on.
    pub fn wait_many(&self, keys: &[&str], timeout: Duration) -> anyhow::Result<Vec<Vec<u8>>> {
        if keys.is_empty() {
            return Ok(Vec::new());
        }
        let ms = timeout.as_millis().min(u64::MAX as u128) as u64;
        let deadline = timeout.saturating_add(WAIT_SLACK);
        let body = encode_wait_many(keys, ms);
        match self.conn.call(Op::WaitMany, "", &body, deadline)? {
            (Status::Ok, v) => {
                let vals = decode_values(&v)?;
                anyhow::ensure!(
                    vals.len() == keys.len(),
                    "WAIT_MANY returned {} values for {} keys",
                    vals.len(),
                    keys.len()
                );
                Ok(vals)
            }
            (Status::Timeout, _) => {
                anyhow::bail!("wait_many({} keys) timeout after {ms} ms", keys.len())
            }
            (s, v) => anyhow::bail!("wait_many failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Batched insert: all pairs land in one round trip, applied
    /// atomically per shard.
    pub fn mset(&self, pairs: &[(&str, &[u8])]) -> anyhow::Result<()> {
        if pairs.is_empty() {
            return Ok(());
        }
        match self.call(Op::MSet, "", &encode_pairs(pairs))? {
            (Status::Ok, _) => Ok(()),
            (s, v) => anyhow::bail!("mset failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Batched fetch: one round trip; `None` per absent key, in request
    /// order.
    pub fn mget(&self, keys: &[&str]) -> anyhow::Result<Vec<Option<Vec<u8>>>> {
        if keys.is_empty() {
            return Ok(Vec::new());
        }
        match self.call(Op::MGet, "", &encode_keys(keys))? {
            (Status::Ok, v) => {
                let vals = decode_maybe_values(&v)?;
                anyhow::ensure!(
                    vals.len() == keys.len(),
                    "MGET returned {} values for {} keys",
                    vals.len(),
                    keys.len()
                );
                Ok(vals)
            }
            (s, v) => anyhow::bail!("mget failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Delete; returns whether the key existed.
    pub fn delete(&self, key: &str) -> anyhow::Result<bool> {
        match self.call(Op::Delete, key, &[])? {
            (Status::Ok, _) => Ok(true),
            (Status::NotFound, _) => Ok(false),
            (s, v) => anyhow::bail!("delete failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Compare-and-set. Returns the value now stored under `key`
    /// (i.e. `new` on success, the conflicting current value otherwise).
    /// PyTorch quirk preserved: empty `old` + missing key ⇒ insert.
    pub fn compare_set(&self, key: &str, old: &[u8], new: &[u8]) -> anyhow::Result<Vec<u8>> {
        let mut val = Vec::with_capacity(4 + old.len() + new.len());
        val.extend_from_slice(&(old.len() as u32).to_le_bytes());
        val.extend_from_slice(old);
        val.extend_from_slice(new);
        match self.call(Op::CompareSet, key, &val)? {
            (Status::Ok, v) => Ok(v),
            (s, v) => anyhow::bail!("compare_set failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// All keys with the given prefix.
    pub fn keys(&self, prefix: &str) -> anyhow::Result<Vec<String>> {
        match self.call(Op::Keys, prefix, &[])? {
            (Status::Ok, v) => {
                let mut out = Vec::new();
                let mut rest = v.as_slice();
                while rest.len() >= 4 {
                    let len = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
                    anyhow::ensure!(rest.len() >= 4 + len, "short KEYS frame");
                    out.push(String::from_utf8(rest[4..4 + len].to_vec())?);
                    rest = &rest[4 + len..];
                }
                Ok(out)
            }
            (s, v) => anyhow::bail!("keys failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Total number of keys.
    pub fn num_keys(&self) -> anyhow::Result<u64> {
        match self.call(Op::NumKeys, "", &[])? {
            (Status::Ok, v) => {
                anyhow::ensure!(v.len() == 8, "short NUM_KEYS frame");
                Ok(u64::from_le_bytes(v.try_into().unwrap()))
            }
            (s, v) => anyhow::bail!("num_keys failed: {s:?} {}", String::from_utf8_lossy(&v)),
        }
    }

    /// Liveness probe.
    pub fn ping(&self) -> anyhow::Result<()> {
        match self.call(Op::Ping, "", &[])? {
            (Status::Ok, _) => Ok(()),
            (s, _) => anyhow::bail!("ping failed: {s:?}"),
        }
    }
}
