//! Wire protocol shared by the store server and client.

use std::io::{Read, Write};

/// Request opcodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Op {
    Set = 1,
    Get = 2,
    Add = 3,
    Wait = 4,
    Delete = 5,
    CompareSet = 6,
    Keys = 7,
    NumKeys = 8,
    Ping = 9,
}

impl Op {
    pub fn from_u8(v: u8) -> anyhow::Result<Self> {
        Ok(match v {
            1 => Op::Set,
            2 => Op::Get,
            3 => Op::Add,
            4 => Op::Wait,
            5 => Op::Delete,
            6 => Op::CompareSet,
            7 => Op::Keys,
            8 => Op::NumKeys,
            9 => Op::Ping,
            _ => anyhow::bail!("bad store op {v}"),
        })
    }
}

/// Response status codes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Status {
    Ok = 0,
    NotFound = 1,
    Timeout = 2,
    Error = 3,
}

impl Status {
    pub fn from_u8(v: u8) -> anyhow::Result<Self> {
        Ok(match v {
            0 => Status::Ok,
            1 => Status::NotFound,
            2 => Status::Timeout,
            3 => Status::Error,
            _ => anyhow::bail!("bad store status {v}"),
        })
    }
}

/// Encode one request frame.
pub fn write_request<W: Write>(w: &mut W, op: Op, key: &str, val: &[u8]) -> anyhow::Result<()> {
    let mut buf = Vec::with_capacity(9 + key.len() + val.len());
    buf.push(op as u8);
    buf.extend_from_slice(&(key.len() as u32).to_le_bytes());
    buf.extend_from_slice(key.as_bytes());
    buf.extend_from_slice(&(val.len() as u32).to_le_bytes());
    buf.extend_from_slice(val);
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Decode one request frame.
pub fn read_request<R: Read>(r: &mut R) -> anyhow::Result<(Op, String, Vec<u8>)> {
    let mut op = [0u8; 1];
    r.read_exact(&mut op)?;
    let op = Op::from_u8(op[0])?;
    let key = read_chunk(r, 1 << 16)?;
    let val = read_chunk(r, 1 << 26)?;
    Ok((op, String::from_utf8(key)?, val))
}

/// Encode one response frame.
pub fn write_response<W: Write>(w: &mut W, status: Status, val: &[u8]) -> anyhow::Result<()> {
    let mut buf = Vec::with_capacity(5 + val.len());
    buf.push(status as u8);
    buf.extend_from_slice(&(val.len() as u32).to_le_bytes());
    buf.extend_from_slice(val);
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Decode one response frame.
pub fn read_response<R: Read>(r: &mut R) -> anyhow::Result<(Status, Vec<u8>)> {
    let mut st = [0u8; 1];
    r.read_exact(&mut st)?;
    let status = Status::from_u8(st[0])?;
    let val = read_chunk(r, 1 << 26)?;
    Ok((status, val))
}

fn read_chunk<R: Read>(r: &mut R, max: usize) -> anyhow::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    anyhow::ensure!(len <= max, "store chunk too large: {len}");
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let mut buf = Vec::new();
        write_request(&mut buf, Op::Set, "hb/w1/0", b"12345").unwrap();
        let (op, key, val) = read_request(&mut buf.as_slice()).unwrap();
        assert_eq!(op, Op::Set);
        assert_eq!(key, "hb/w1/0");
        assert_eq!(val, b"12345");
    }

    #[test]
    fn response_roundtrip() {
        let mut buf = Vec::new();
        write_response(&mut buf, Status::Timeout, b"").unwrap();
        let (st, val) = read_response(&mut buf.as_slice()).unwrap();
        assert_eq!(st, Status::Timeout);
        assert!(val.is_empty());
    }

    #[test]
    fn rejects_oversized_key() {
        // key length field says 1 MiB — beyond the 64 KiB key cap.
        let mut buf = vec![Op::Get as u8];
        buf.extend_from_slice(&(1u32 << 20).to_le_bytes());
        buf.extend_from_slice(&[0u8; 16]);
        assert!(read_request(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn op_status_tags() {
        for op in [Op::Set, Op::Get, Op::Add, Op::Wait, Op::Delete, Op::CompareSet, Op::Keys, Op::NumKeys, Op::Ping] {
            assert_eq!(Op::from_u8(op as u8).unwrap(), op);
        }
        assert!(Op::from_u8(0).is_err());
        for st in [Status::Ok, Status::NotFound, Status::Timeout, Status::Error] {
            assert_eq!(Status::from_u8(st as u8).unwrap(), st);
        }
        assert!(Status::from_u8(9).is_err());
    }
}
