//! Wire protocol shared by the store server and client.
//!
//! Every request carries a client-chosen **correlation id** echoed in
//! its response, so one connection can have many requests in flight and
//! responses may return out of order (a registered `WAIT` answers when
//! its key lands, while later `SET`s on the same connection answer
//! immediately). This is what lets the client pool one pipelined
//! connection per `(process, server)` instead of one per handle.
//!
//! ```text
//!   request  = id:u64  op:u8  key_len:u32  key  val_len:u32  val
//!   response = id:u64  status:u8  val_len:u32  val
//! ```
//!
//! Batched verbs pack their operands into `val` (the `key` field is
//! empty): see [`encode_pairs`] / [`encode_keys`] and the per-op notes
//! on [`Op`].

use std::io::{Read, Write};

/// Hard cap on key length (bytes). Enforced on both ends: the client
/// rejects oversized keys before they touch the wire, the server
/// rejects them on read (a malicious or corrupt frame must not balloon
/// server memory).
pub const MAX_KEY: usize = 1 << 16;
/// Hard cap on value length (bytes), ditto.
pub const MAX_VAL: usize = 1 << 26;

/// Request opcodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Op {
    Set = 1,
    Get = 2,
    Add = 3,
    Wait = 4,
    Delete = 5,
    CompareSet = 6,
    Keys = 7,
    NumKeys = 8,
    Ping = 9,
    /// Batched set: `val = count:u32 (klen:u32 key vlen:u32 val)*`,
    /// applied atomically per shard (all keys land before any waiter
    /// on them is answered).
    MSet = 10,
    /// Batched get: `val = count:u32 (klen:u32 key)*`; response `val =
    /// (present:u8 vlen:u32 val)*` in request order.
    MGet = 11,
    /// Wait until **all** keys exist: `val = timeout_ms:u64 count:u32
    /// (klen:u32 key)*`; response `Ok` with `(vlen:u32 val)*` in
    /// request order once every key is present, `Timeout` otherwise
    /// (all-or-nothing: no partial values on timeout).
    WaitMany = 12,
}

impl Op {
    pub fn from_u8(v: u8) -> anyhow::Result<Self> {
        Ok(match v {
            1 => Op::Set,
            2 => Op::Get,
            3 => Op::Add,
            4 => Op::Wait,
            5 => Op::Delete,
            6 => Op::CompareSet,
            7 => Op::Keys,
            8 => Op::NumKeys,
            9 => Op::Ping,
            10 => Op::MSet,
            11 => Op::MGet,
            12 => Op::WaitMany,
            _ => anyhow::bail!("bad store op {v}"),
        })
    }
}

/// Response status codes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Status {
    Ok = 0,
    NotFound = 1,
    Timeout = 2,
    Error = 3,
}

impl Status {
    pub fn from_u8(v: u8) -> anyhow::Result<Self> {
        Ok(match v {
            0 => Status::Ok,
            1 => Status::NotFound,
            2 => Status::Timeout,
            3 => Status::Error,
            _ => anyhow::bail!("bad store status {v}"),
        })
    }
}

/// Encode one request frame.
pub fn write_request<W: Write>(
    w: &mut W,
    id: u64,
    op: Op,
    key: &str,
    val: &[u8],
) -> anyhow::Result<()> {
    anyhow::ensure!(key.len() <= MAX_KEY, "store key too large: {}", key.len());
    anyhow::ensure!(val.len() <= MAX_VAL, "store value too large: {}", val.len());
    let mut buf = Vec::with_capacity(17 + key.len() + val.len());
    buf.extend_from_slice(&id.to_le_bytes());
    buf.push(op as u8);
    buf.extend_from_slice(&(key.len() as u32).to_le_bytes());
    buf.extend_from_slice(key.as_bytes());
    buf.extend_from_slice(&(val.len() as u32).to_le_bytes());
    buf.extend_from_slice(val);
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Decode one request frame.
pub fn read_request<R: Read>(r: &mut R) -> anyhow::Result<(u64, Op, String, Vec<u8>)> {
    let mut id = [0u8; 8];
    r.read_exact(&mut id)?;
    let id = u64::from_le_bytes(id);
    let mut op = [0u8; 1];
    r.read_exact(&mut op)?;
    let op = Op::from_u8(op[0])?;
    let key = read_chunk(r, MAX_KEY)?;
    let val = read_chunk(r, MAX_VAL)?;
    Ok((id, op, String::from_utf8(key)?, val))
}

/// Encode one response frame.
pub fn write_response<W: Write>(
    w: &mut W,
    id: u64,
    status: Status,
    val: &[u8],
) -> anyhow::Result<()> {
    let mut buf = Vec::with_capacity(13 + val.len());
    buf.extend_from_slice(&id.to_le_bytes());
    buf.push(status as u8);
    buf.extend_from_slice(&(val.len() as u32).to_le_bytes());
    buf.extend_from_slice(val);
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Decode one response frame.
pub fn read_response<R: Read>(r: &mut R) -> anyhow::Result<(u64, Status, Vec<u8>)> {
    let mut id = [0u8; 8];
    r.read_exact(&mut id)?;
    let id = u64::from_le_bytes(id);
    let mut st = [0u8; 1];
    r.read_exact(&mut st)?;
    let status = Status::from_u8(st[0])?;
    let val = read_chunk(r, MAX_VAL)?;
    Ok((id, status, val))
}

fn read_chunk<R: Read>(r: &mut R, max: usize) -> anyhow::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    anyhow::ensure!(len <= max, "store chunk too large: {len}");
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Pack `(key, value)` pairs into an [`Op::MSet`] operand.
pub fn encode_pairs(pairs: &[(&str, &[u8])]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(pairs.len() as u32).to_le_bytes());
    for (k, v) in pairs {
        out.extend_from_slice(&(k.len() as u32).to_le_bytes());
        out.extend_from_slice(k.as_bytes());
        out.extend_from_slice(&(v.len() as u32).to_le_bytes());
        out.extend_from_slice(v);
    }
    out
}

/// Unpack an [`Op::MSet`] operand.
pub fn decode_pairs(mut val: &[u8]) -> anyhow::Result<Vec<(String, Vec<u8>)>> {
    let count = take_u32(&mut val)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let k = take_chunk(&mut val, MAX_KEY)?;
        let v = take_chunk(&mut val, MAX_VAL)?;
        out.push((String::from_utf8(k)?, v));
    }
    Ok(out)
}

/// Pack a key list into an [`Op::MGet`] / [`Op::WaitMany`] operand
/// (the latter prefixes a timeout — see [`encode_wait_many`]).
pub fn encode_keys(keys: &[&str]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(keys.len() as u32).to_le_bytes());
    for k in keys {
        out.extend_from_slice(&(k.len() as u32).to_le_bytes());
        out.extend_from_slice(k.as_bytes());
    }
    out
}

/// Unpack a key list.
pub fn decode_keys(mut val: &[u8]) -> anyhow::Result<Vec<String>> {
    let count = take_u32(&mut val)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        out.push(String::from_utf8(take_chunk(&mut val, MAX_KEY)?)?);
    }
    Ok(out)
}

/// Pack an [`Op::WaitMany`] operand: timeout + key list.
pub fn encode_wait_many(keys: &[&str], timeout_ms: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(8);
    out.extend_from_slice(&timeout_ms.to_le_bytes());
    out.extend_from_slice(&encode_keys(keys));
    out
}

/// Unpack an [`Op::WaitMany`] operand.
pub fn decode_wait_many(val: &[u8]) -> anyhow::Result<(u64, Vec<String>)> {
    anyhow::ensure!(val.len() >= 8, "short WAIT_MANY operand");
    let timeout = u64::from_le_bytes(val[0..8].try_into().unwrap());
    Ok((timeout, decode_keys(&val[8..])?))
}

/// Pack values (an [`Op::WaitMany`] `Ok` response body).
pub fn encode_values(values: &[Vec<u8>]) -> Vec<u8> {
    let mut out = Vec::new();
    for v in values {
        out.extend_from_slice(&(v.len() as u32).to_le_bytes());
        out.extend_from_slice(v);
    }
    out
}

/// Unpack a values list ([`Op::WaitMany`] response body).
pub fn decode_values(mut val: &[u8]) -> anyhow::Result<Vec<Vec<u8>>> {
    let mut out = Vec::new();
    while !val.is_empty() {
        out.push(take_chunk(&mut val, MAX_VAL)?);
    }
    Ok(out)
}

/// Pack `(present, value)` entries (an [`Op::MGet`] response body).
pub fn encode_maybe_values(values: &[Option<&[u8]>]) -> Vec<u8> {
    let mut out = Vec::new();
    for v in values {
        match v {
            Some(v) => {
                out.push(1);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                out.extend_from_slice(v);
            }
            None => {
                out.push(0);
                out.extend_from_slice(&0u32.to_le_bytes());
            }
        }
    }
    out
}

/// Unpack an [`Op::MGet`] response body.
pub fn decode_maybe_values(mut val: &[u8]) -> anyhow::Result<Vec<Option<Vec<u8>>>> {
    let mut out = Vec::new();
    while !val.is_empty() {
        anyhow::ensure!(!val.is_empty(), "short MGET frame");
        let present = val[0] == 1;
        val = &val[1..];
        let v = take_chunk(&mut val, MAX_VAL)?;
        out.push(if present { Some(v) } else { None });
    }
    Ok(out)
}

fn take_u32(buf: &mut &[u8]) -> anyhow::Result<u32> {
    anyhow::ensure!(buf.len() >= 4, "short store frame");
    let v = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    *buf = &buf[4..];
    Ok(v)
}

fn take_chunk(buf: &mut &[u8], max: usize) -> anyhow::Result<Vec<u8>> {
    let len = take_u32(buf)? as usize;
    anyhow::ensure!(len <= max, "store chunk too large: {len}");
    anyhow::ensure!(buf.len() >= len, "short store frame");
    let out = buf[..len].to_vec();
    *buf = &buf[len..];
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let mut buf = Vec::new();
        write_request(&mut buf, 42, Op::Set, "hb/w1/0", b"12345").unwrap();
        let (id, op, key, val) = read_request(&mut buf.as_slice()).unwrap();
        assert_eq!(id, 42);
        assert_eq!(op, Op::Set);
        assert_eq!(key, "hb/w1/0");
        assert_eq!(val, b"12345");
    }

    #[test]
    fn response_roundtrip() {
        let mut buf = Vec::new();
        write_response(&mut buf, 7, Status::Timeout, b"").unwrap();
        let (id, st, val) = read_response(&mut buf.as_slice()).unwrap();
        assert_eq!(id, 7);
        assert_eq!(st, Status::Timeout);
        assert!(val.is_empty());
    }

    #[test]
    fn rejects_oversized_key() {
        // key length field says 1 MiB — beyond the 64 KiB key cap.
        let mut buf = 1u64.to_le_bytes().to_vec();
        buf.push(Op::Get as u8);
        buf.extend_from_slice(&(1u32 << 20).to_le_bytes());
        buf.extend_from_slice(&[0u8; 16]);
        assert!(read_request(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_oversized_key_on_write() {
        let big = "k".repeat(MAX_KEY + 1);
        let mut buf = Vec::new();
        assert!(write_request(&mut buf, 1, Op::Set, &big, b"").is_err());
        assert!(buf.is_empty(), "nothing hits the wire");
    }

    #[test]
    fn op_status_tags() {
        for op in [
            Op::Set,
            Op::Get,
            Op::Add,
            Op::Wait,
            Op::Delete,
            Op::CompareSet,
            Op::Keys,
            Op::NumKeys,
            Op::Ping,
            Op::MSet,
            Op::MGet,
            Op::WaitMany,
        ] {
            assert_eq!(Op::from_u8(op as u8).unwrap(), op);
        }
        assert!(Op::from_u8(0).is_err());
        for st in [Status::Ok, Status::NotFound, Status::Timeout, Status::Error] {
            assert_eq!(Status::from_u8(st as u8).unwrap(), st);
        }
        assert!(Status::from_u8(9).is_err());
    }

    #[test]
    fn batch_encodings_roundtrip() {
        let pairs: Vec<(&str, &[u8])> = vec![("a", b"1"), ("b/c", b""), ("d", b"xyz")];
        let decoded = decode_pairs(&encode_pairs(&pairs)).unwrap();
        assert_eq!(decoded.len(), 3);
        assert_eq!(decoded[0], ("a".to_string(), b"1".to_vec()));
        assert_eq!(decoded[1], ("b/c".to_string(), Vec::new()));

        let keys = ["k0", "k1", "k2"];
        let (t, ks) = decode_wait_many(&encode_wait_many(&keys, 1234)).unwrap();
        assert_eq!(t, 1234);
        assert_eq!(ks, vec!["k0", "k1", "k2"]);

        let vals = vec![b"one".to_vec(), Vec::new(), b"three".to_vec()];
        assert_eq!(decode_values(&encode_values(&vals)).unwrap(), vals);

        let maybes: Vec<Option<&[u8]>> = vec![Some(b"v"), None, Some(b"")];
        let decoded = decode_maybe_values(&encode_maybe_values(&maybes)).unwrap();
        assert_eq!(decoded, vec![Some(b"v".to_vec()), None, Some(Vec::new())]);
    }
}
