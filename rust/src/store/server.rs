//! The store server: sharded key space, push-based waits.
//!
//! The key space is hashed across `MW_STORE_SHARDS` independent lock
//! domains (default 8) so concurrent world inits touching disjoint key
//! prefixes never contend on one global mutex. `WAIT`/`WAIT_MANY` no
//! longer park the connection thread in a condvar poll: the request
//! registers a **waiter** under the shard(s) of its key(s) and the
//! connection goes straight back to reading; whichever write lands the
//! last missing key answers the wait from the writer's thread
//! (notify-on-write). A single timer thread owns a deadline heap and
//! answers `Timeout` for waits that never complete, so an idle server
//! burns no CPU regardless of how many waits are parked.
//!
//! Responses are written through a per-connection mutexed writer and
//! carry the request's correlation id, so they may interleave out of
//! request order — the pipelined client demuxes by id.
//!
//! Cross-shard aggregate ops (`KEYS`, `NUM_KEYS`) lock shards one at a
//! time: they see a consistent per-shard view, not a global snapshot.
//! Waiters whose client disconnects before the deadline linger until
//! the deadline fires (the timeout write to the dead socket is simply
//! discarded) — a bounded, self-cleaning leak.

use super::protocol::{
    decode_keys, decode_pairs, decode_wait_many, encode_maybe_values, encode_values, read_request,
    write_response, Op, Status,
};
use std::collections::{BTreeMap, BinaryHeap, HashMap};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Default shard count when `MW_STORE_SHARDS` is unset.
const DEFAULT_SHARDS: usize = 8;

fn shard_count_from_env() -> usize {
    std::env::var("MW_STORE_SHARDS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(DEFAULT_SHARDS)
}

/// FNV-1a; stable across platforms so shard placement is deterministic
/// for a given key and shard count.
fn shard_of(key: &str, shards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    (h % shards as u64) as usize
}

/// Per-connection response writer. Shared by the connection's reader
/// thread (immediate ops), writer threads fulfilling waits, and the
/// timer thread — the mutex keeps frames whole; correlation ids make
/// interleaving safe.
struct ConnTx {
    writer: Mutex<TcpStream>,
}

impl ConnTx {
    /// Best-effort: a dead client simply stops receiving responses.
    fn send(&self, id: u64, status: Status, body: &[u8]) {
        let mut w = self.writer.lock().unwrap();
        let _ = write_response(&mut *w, id, status, body);
    }
}

#[derive(Clone, Copy)]
enum WaitKind {
    Single,
    Many,
}

/// A parked `WAIT`/`WAIT_MANY`. `remaining` counts unfilled slots; the
/// thread whose fill drives it to zero answers. `done` guards
/// exactly-once response between fulfillment and timeout.
struct Waiter {
    id: u64,
    tx: Arc<ConnTx>,
    kind: WaitKind,
    remaining: AtomicUsize,
    slots: Mutex<Vec<Option<Vec<u8>>>>,
    done: AtomicBool,
    keys: Vec<String>,
}

impl Waiter {
    /// Fill one slot; returns true iff this fill completed the set.
    fn fill(&self, slot: usize, val: Vec<u8>) -> bool {
        let mut slots = self.slots.lock().unwrap();
        if slots[slot].is_some() {
            return false;
        }
        slots[slot] = Some(val);
        drop(slots);
        self.remaining.fetch_sub(1, Ordering::AcqRel) == 1
    }

    fn respond_ready(&self) {
        if self.done.swap(true, Ordering::AcqRel) {
            return;
        }
        let slots = self.slots.lock().unwrap();
        let body = match self.kind {
            WaitKind::Single => slots.first().and_then(|s| s.clone()).unwrap_or_default(),
            WaitKind::Many => {
                let vals: Vec<Vec<u8>> =
                    slots.iter().map(|s| s.clone().unwrap_or_default()).collect();
                encode_values(&vals)
            }
        };
        drop(slots);
        self.tx.send(self.id, Status::Ok, &body);
    }
}

#[derive(Default)]
struct ShardInner {
    map: BTreeMap<String, Vec<u8>>,
    /// Parked waiters per missing key: `(waiter, slot index)`.
    waiters: HashMap<String, Vec<(Arc<Waiter>, usize)>>,
}

#[derive(Default)]
struct Shard {
    inner: Mutex<ShardInner>,
}

/// Insert and wake: fills every waiter parked on `key`; waiters whose
/// set completed are pushed onto `ready` for the caller to answer
/// *after* the shard lock drops (socket writes never run under it).
fn insert_notify(inner: &mut ShardInner, key: &str, val: Vec<u8>, ready: &mut Vec<Arc<Waiter>>) {
    if let Some(ws) = inner.waiters.remove(key) {
        for (w, slot) in ws {
            if w.fill(slot, val.clone()) {
                ready.push(w);
            }
        }
    }
    inner.map.insert(key.to_string(), val);
}

/// Deadline heap entry; min-heap by deadline via reversed `Ord`.
struct TimerEntry {
    deadline: Instant,
    waiter: Arc<Waiter>,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.deadline == other.deadline
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimerEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.deadline.cmp(&self.deadline)
    }
}

#[derive(Default)]
struct Timer {
    queue: Mutex<BinaryHeap<TimerEntry>>,
    wake: Condvar,
    stop: AtomicBool,
}

impl Timer {
    fn push(&self, waiter: Arc<Waiter>, deadline: Instant) {
        self.queue.lock().unwrap().push(TimerEntry { deadline, waiter });
        // Always wake: the new entry may front-run the current minimum.
        self.wake.notify_one();
    }
}

/// Timer thread body: sleep exactly until the next deadline (or until a
/// registration / shutdown wakes us), then expire everything due.
fn timer_loop(timer: Arc<Timer>, shards: Arc<Vec<Shard>>) {
    loop {
        let mut due: Vec<Arc<Waiter>> = Vec::new();
        {
            let mut q = timer.queue.lock().unwrap();
            loop {
                if timer.stop.load(Ordering::Relaxed) {
                    return;
                }
                let now = Instant::now();
                while q.peek().is_some_and(|t| t.deadline <= now) {
                    due.push(q.pop().unwrap().waiter);
                }
                if !due.is_empty() {
                    break;
                }
                match q.peek().map(|t| t.deadline.saturating_duration_since(now)) {
                    Some(d) => q = timer.wake.wait_timeout(q, d).unwrap().0,
                    None => q = timer.wake.wait(q).unwrap(),
                }
            }
        }
        for w in due {
            expire(&w, &shards);
        }
    }
}

/// Answer `Timeout` (unless already fulfilled) and unregister from
/// every shard the waiter still parks on.
fn expire(w: &Arc<Waiter>, shards: &[Shard]) {
    if w.done.swap(true, Ordering::AcqRel) {
        return; // fulfilled first; writers already unregistered it
    }
    for key in &w.keys {
        let mut inner = shards[shard_of(key, shards.len())].inner.lock().unwrap();
        if let Some(ws) = inner.waiters.get_mut(key) {
            ws.retain(|(other, _)| !Arc::ptr_eq(other, w));
            if ws.is_empty() {
                inner.waiters.remove(key);
            }
        }
    }
    w.tx.send(w.id, Status::Timeout, &[]);
}

/// A TCPStore server. Dropping it stops the acceptor, closes the port
/// AND severs established connections — a dead store must look dead to
/// its clients (the watchdog relies on `store unreachable` as a
/// world-leader-death signal).
pub struct StoreServer {
    addr: SocketAddr,
    shards: Arc<Vec<Shard>>,
    timer: Arc<Timer>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    timer_thread: Option<std::thread::JoinHandle<()>>,
}

impl StoreServer {
    /// Bind an ephemeral localhost port.
    pub fn bind_any() -> anyhow::Result<Self> {
        Self::bind("127.0.0.1:0")
    }

    pub fn bind(addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let nshards = shard_count_from_env();
        let shards: Arc<Vec<Shard>> = Arc::new((0..nshards).map(|_| Shard::default()).collect());
        let timer = Arc::new(Timer::default());
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));

        let timer_thread = {
            let timer = timer.clone();
            let shards = shards.clone();
            std::thread::Builder::new()
                .name(format!("store-timer-{}", addr.port()))
                .spawn(move || timer_loop(timer, shards))?
        };

        let accept_thread = {
            let shards = shards.clone();
            let timer = timer.clone();
            let stop = stop.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name(format!("store-accept-{}", addr.port()))
                .spawn(move || {
                    // Blocking accept: drop() wakes us with a throwaway
                    // connect to our own port (no poll loop).
                    loop {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                if stop.load(Ordering::Relaxed) {
                                    break;
                                }
                                if let Ok(dup) = stream.try_clone() {
                                    conns.lock().unwrap().push(dup);
                                }
                                let shards = shards.clone();
                                let timer = timer.clone();
                                let _ = std::thread::Builder::new()
                                    .name("store-conn".into())
                                    .spawn(move || handle_conn(stream, shards, timer));
                            }
                            Err(_) => {
                                if stop.load(Ordering::Relaxed) {
                                    break;
                                }
                            }
                        }
                    }
                })?
        };

        Ok(StoreServer {
            addr,
            shards,
            timer,
            stop,
            conns,
            accept_thread: Some(accept_thread),
            timer_thread: Some(timer_thread),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Number of keys currently stored (for tests/diagnostics).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.inner.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Drop for StoreServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking acceptor with a throwaway connection.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        // Stop the timer thread.
        self.timer.stop.store(true, Ordering::Relaxed);
        self.timer.wake.notify_all();
        // Sever established connections: clients must observe the death
        // immediately, exactly as if the hosting process was killed.
        for conn in self.conns.lock().unwrap().drain(..) {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.timer_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(stream: TcpStream, shards: Arc<Vec<Shard>>, timer: Arc<Timer>) {
    let _ = stream.set_nodelay(true);
    let tx = match stream.try_clone() {
        Ok(w) => Arc::new(ConnTx { writer: Mutex::new(w) }),
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let (id, op, key, val) = match read_request(&mut reader) {
            Ok(r) => r,
            Err(_) => return, // client went away
        };
        match op {
            Op::Wait | Op::WaitMany => {
                if let Err(e) = register_wait(&shards, &timer, &tx, id, op, &key, &val) {
                    tx.send(id, Status::Error, e.to_string().as_bytes());
                }
            }
            _ => {
                let mut ready: Vec<Arc<Waiter>> = Vec::new();
                let (status, out) = match apply(&shards, op, &key, &val, &mut ready) {
                    Ok((s, v)) => (s, v),
                    Err(e) => (Status::Error, e.to_string().into_bytes()),
                };
                tx.send(id, status, &out);
                // Shard locks are released: now answer any waits this
                // write completed.
                for w in ready {
                    w.respond_ready();
                }
            }
        }
    }
}

/// Park a `WAIT`/`WAIT_MANY`. Keys that already exist fill their slot
/// immediately (under the shard lock, so there is no check-then-register
/// window); missing keys register the waiter for notify-on-write.
fn register_wait(
    shards: &[Shard],
    timer: &Timer,
    tx: &Arc<ConnTx>,
    id: u64,
    op: Op,
    key: &str,
    val: &[u8],
) -> anyhow::Result<()> {
    let (kind, timeout_ms, keys) = match op {
        Op::Wait => {
            anyhow::ensure!(val.len() == 8, "WAIT takes u64 timeout ms");
            let t = u64::from_le_bytes(val.try_into().unwrap());
            (WaitKind::Single, t, vec![key.to_string()])
        }
        Op::WaitMany => {
            let (t, keys) = decode_wait_many(val)?;
            (WaitKind::Many, t, keys)
        }
        _ => unreachable!("register_wait only handles wait ops"),
    };
    let n = keys.len();
    let waiter = Arc::new(Waiter {
        id,
        tx: tx.clone(),
        kind,
        remaining: AtomicUsize::new(n),
        slots: Mutex::new(vec![None; n]),
        done: AtomicBool::new(false),
        keys: keys.clone(),
    });
    let mut completed_here = n == 0; // empty WAIT_MANY is trivially ready
    for (slot, k) in keys.iter().enumerate() {
        let mut inner = shards[shard_of(k, shards.len())].inner.lock().unwrap();
        if let Some(v) = inner.map.get(k) {
            let v = v.clone();
            drop(inner);
            if waiter.fill(slot, v) {
                completed_here = true;
            }
        } else {
            inner.waiters.entry(k.clone()).or_default().push((waiter.clone(), slot));
        }
    }
    if completed_here {
        waiter.respond_ready();
    } else {
        // May already be fulfilled by a concurrent writer — the expiry
        // then finds `done` set and is a no-op.
        timer.push(waiter, Instant::now() + Duration::from_millis(timeout_ms));
    }
    Ok(())
}

fn apply(
    shards: &[Shard],
    op: Op,
    key: &str,
    val: &[u8],
    ready: &mut Vec<Arc<Waiter>>,
) -> anyhow::Result<(Status, Vec<u8>)> {
    let nsh = shards.len();
    match op {
        Op::Ping => Ok((Status::Ok, b"pong".to_vec())),
        Op::Set => {
            let mut inner = shards[shard_of(key, nsh)].inner.lock().unwrap();
            insert_notify(&mut inner, key, val.to_vec(), ready);
            Ok((Status::Ok, Vec::new()))
        }
        Op::Get => {
            let inner = shards[shard_of(key, nsh)].inner.lock().unwrap();
            match inner.map.get(key) {
                Some(v) => Ok((Status::Ok, v.clone())),
                None => Ok((Status::NotFound, Vec::new())),
            }
        }
        Op::Add => {
            anyhow::ensure!(val.len() == 8, "ADD takes i64");
            let delta = i64::from_le_bytes(val.try_into().unwrap());
            let mut inner = shards[shard_of(key, nsh)].inner.lock().unwrap();
            let cur: i64 = inner
                .map
                .get(key)
                .and_then(|v| std::str::from_utf8(v).ok())
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            let next = cur + delta;
            let bytes = next.to_string().into_bytes();
            insert_notify(&mut inner, key, bytes.clone(), ready);
            Ok((Status::Ok, bytes))
        }
        Op::Delete => {
            let mut inner = shards[shard_of(key, nsh)].inner.lock().unwrap();
            let existed = inner.map.remove(key).is_some();
            Ok((
                if existed { Status::Ok } else { Status::NotFound },
                Vec::new(),
            ))
        }
        Op::CompareSet => {
            // val = old_len:u32 old new
            anyhow::ensure!(val.len() >= 4, "COMPARE_SET frame too short");
            let old_len = u32::from_le_bytes(val[0..4].try_into().unwrap()) as usize;
            anyhow::ensure!(val.len() >= 4 + old_len, "COMPARE_SET old truncated");
            let old = &val[4..4 + old_len];
            let new = &val[4 + old_len..];
            let mut inner = shards[shard_of(key, nsh)].inner.lock().unwrap();
            let cur = inner.map.get(key).cloned();
            let out = match cur {
                None if old.is_empty() => {
                    insert_notify(&mut inner, key, new.to_vec(), ready);
                    new.to_vec()
                }
                None => Vec::new(), // missing and expectation non-empty: no-op
                Some(c) if c == old => {
                    insert_notify(&mut inner, key, new.to_vec(), ready);
                    new.to_vec()
                }
                Some(c) => c,
            };
            Ok((Status::Ok, out))
        }
        Op::Keys => {
            let mut all: Vec<String> = Vec::new();
            for sh in shards {
                let inner = sh.inner.lock().unwrap();
                all.extend(inner.map.keys().filter(|k| k.starts_with(key)).cloned());
            }
            all.sort();
            let mut out = Vec::new();
            for k in all {
                out.extend_from_slice(&(k.len() as u32).to_le_bytes());
                out.extend_from_slice(k.as_bytes());
            }
            Ok((Status::Ok, out))
        }
        Op::NumKeys => {
            let n: u64 = shards
                .iter()
                .map(|s| s.inner.lock().unwrap().map.len() as u64)
                .sum();
            Ok((Status::Ok, n.to_le_bytes().to_vec()))
        }
        Op::MSet => {
            let pairs = decode_pairs(val)?;
            let mut by_shard: Vec<Vec<(String, Vec<u8>)>> = (0..nsh).map(|_| Vec::new()).collect();
            for (k, v) in pairs {
                by_shard[shard_of(&k, nsh)].push((k, v));
            }
            for (i, batch) in by_shard.into_iter().enumerate() {
                if batch.is_empty() {
                    continue;
                }
                let mut inner = shards[i].inner.lock().unwrap();
                for (k, v) in batch {
                    insert_notify(&mut inner, &k, v, ready);
                }
            }
            Ok((Status::Ok, Vec::new()))
        }
        Op::MGet => {
            let keys = decode_keys(val)?;
            let mut vals: Vec<Option<Vec<u8>>> = Vec::with_capacity(keys.len());
            for k in &keys {
                let inner = shards[shard_of(k, nsh)].inner.lock().unwrap();
                vals.push(inner.map.get(k).cloned());
            }
            let refs: Vec<Option<&[u8]>> = vals.iter().map(|v| v.as_deref()).collect();
            Ok((Status::Ok, encode_maybe_values(&refs)))
        }
        Op::Wait | Op::WaitMany => unreachable!("wait ops are handled by register_wait"),
    }
}
