//! The store server: one thread per client connection, shared map with
//! condvar wakeups for WAIT.

use super::protocol::{read_request, write_response, Op, Status};
use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Default)]
struct Shared {
    map: Mutex<BTreeMap<String, Vec<u8>>>,
    changed: Condvar,
}

/// A TCPStore server. Dropping it stops the acceptor, closes the port
/// AND severs established connections — a dead store must look dead to
/// its clients (the watchdog relies on `store unreachable` as a
/// world-leader-death signal).
pub struct StoreServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl StoreServer {
    /// Bind an ephemeral localhost port.
    pub fn bind_any() -> anyhow::Result<Self> {
        Self::bind("127.0.0.1:0")
    }

    pub fn bind(addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        // Poll the listener so drop() can stop the acceptor promptly.
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared::default());
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let s2 = shared.clone();
        let stop2 = stop.clone();
        let conns2 = conns.clone();
        let accept_thread = std::thread::Builder::new()
            .name(format!("store-accept-{}", addr.port()))
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if let Ok(dup) = stream.try_clone() {
                                conns2.lock().unwrap().push(dup);
                            }
                            let s3 = s2.clone();
                            let stop3 = stop2.clone();
                            let _ = std::thread::Builder::new()
                                .name("store-conn".into())
                                .spawn(move || handle_conn(stream, s3, stop3));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(StoreServer { addr, shared, stop, conns, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Number of keys currently stored (for tests/diagnostics).
    pub fn len(&self) -> usize {
        self.shared.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Drop for StoreServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake any blocked WAITs so their connections notice shutdown.
        self.shared.changed.notify_all();
        // Sever established connections: clients must observe the death
        // immediately, exactly as if the hosting process was killed.
        for conn in self.conns.lock().unwrap().drain(..) {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(stream: TcpStream, shared: Arc<Shared>, stop: Arc<AtomicBool>) {
    let _ = stream.set_nodelay(true);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let (op, key, val) = match read_request(&mut reader) {
            Ok(r) => r,
            Err(_) => return, // client went away
        };
        let result = apply(&shared, &stop, op, &key, &val);
        let (status, out) = match result {
            Ok((s, v)) => (s, v),
            Err(e) => (Status::Error, e.to_string().into_bytes()),
        };
        if write_response(&mut writer, status, &out).is_err() {
            return;
        }
    }
}

fn apply(
    shared: &Shared,
    stop: &AtomicBool,
    op: Op,
    key: &str,
    val: &[u8],
) -> anyhow::Result<(Status, Vec<u8>)> {
    match op {
        Op::Ping => Ok((Status::Ok, b"pong".to_vec())),
        Op::Set => {
            let mut m = shared.map.lock().unwrap();
            m.insert(key.to_string(), val.to_vec());
            shared.changed.notify_all();
            Ok((Status::Ok, Vec::new()))
        }
        Op::Get => {
            let m = shared.map.lock().unwrap();
            match m.get(key) {
                Some(v) => Ok((Status::Ok, v.clone())),
                None => Ok((Status::NotFound, Vec::new())),
            }
        }
        Op::Add => {
            anyhow::ensure!(val.len() == 8, "ADD takes i64");
            let delta = i64::from_le_bytes(val.try_into().unwrap());
            let mut m = shared.map.lock().unwrap();
            let cur: i64 = m
                .get(key)
                .and_then(|v| std::str::from_utf8(v).ok())
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            let next = cur + delta;
            m.insert(key.to_string(), next.to_string().into_bytes());
            shared.changed.notify_all();
            Ok((Status::Ok, next.to_string().into_bytes()))
        }
        Op::Wait => {
            anyhow::ensure!(val.len() == 8, "WAIT takes u64 timeout ms");
            let timeout = Duration::from_millis(u64::from_le_bytes(val.try_into().unwrap()));
            let deadline = Instant::now() + timeout;
            let mut m = shared.map.lock().unwrap();
            loop {
                if let Some(v) = m.get(key) {
                    return Ok((Status::Ok, v.clone()));
                }
                if stop.load(Ordering::Relaxed) {
                    return Ok((Status::Error, b"server shutting down".to_vec()));
                }
                let now = Instant::now();
                if now >= deadline {
                    return Ok((Status::Timeout, Vec::new()));
                }
                let (guard, _timeout) = shared
                    .changed
                    .wait_timeout(m, (deadline - now).min(Duration::from_millis(100)))
                    .unwrap();
                m = guard;
            }
        }
        Op::Delete => {
            let mut m = shared.map.lock().unwrap();
            let existed = m.remove(key).is_some();
            Ok((
                if existed { Status::Ok } else { Status::NotFound },
                Vec::new(),
            ))
        }
        Op::CompareSet => {
            // val = old_len:u32 old new
            anyhow::ensure!(val.len() >= 4, "COMPARE_SET frame too short");
            let old_len = u32::from_le_bytes(val[0..4].try_into().unwrap()) as usize;
            anyhow::ensure!(val.len() >= 4 + old_len, "COMPARE_SET old truncated");
            let old = &val[4..4 + old_len];
            let new = &val[4 + old_len..];
            let mut m = shared.map.lock().unwrap();
            let cur = m.get(key).cloned();
            let out = match cur {
                None if old.is_empty() => {
                    m.insert(key.to_string(), new.to_vec());
                    shared.changed.notify_all();
                    new.to_vec()
                }
                None => Vec::new(), // missing and expectation non-empty: no-op
                Some(c) if c == old => {
                    m.insert(key.to_string(), new.to_vec());
                    shared.changed.notify_all();
                    new.to_vec()
                }
                Some(c) => c,
            };
            Ok((Status::Ok, out))
        }
        Op::Keys => {
            let m = shared.map.lock().unwrap();
            let mut out = Vec::new();
            for k in m.keys() {
                if k.starts_with(key) {
                    out.extend_from_slice(&(k.len() as u32).to_le_bytes());
                    out.extend_from_slice(k.as_bytes());
                }
            }
            Ok((Status::Ok, out))
        }
        Op::NumKeys => {
            let m = shared.map.lock().unwrap();
            Ok((Status::Ok, (m.len() as u64).to_le_bytes().to_vec()))
        }
    }
}
