//! TCPStore — a PyTorch-compatible-in-spirit key-value store over TCP,
//! rebuilt for control-plane throughput.
//!
//! PyTorch creates one `TCPStore` per process group during `init`; the
//! paper's watchdog piggybacks worker heartbeats on exactly that store
//! ("It relies on TCPStore created by PyTorch during the initialization
//! of a world. One TCPStore instance is associated with one world.").
//! We reproduce that: the world *leader* hosts a [`StoreServer`]; every
//! member connects a [`StoreClient`]. Rendezvous, rank assignment,
//! address exchange and heartbeats all flow through it.
//!
//! ## Architecture
//!
//! * **Sharded server** — the key space is FNV-hashed across
//!   `MW_STORE_SHARDS` (default 8) independent lock domains, so
//!   concurrent world inits on disjoint key prefixes never serialize on
//!   one mutex.
//! * **Push-based waits** — `WAIT`/`WAIT_MANY` register a waiter under
//!   the shard(s) of their key(s) and free the connection thread; the
//!   write that lands the last missing key answers the wait
//!   (notify-on-write), and a single timer thread answers `Timeout`.
//!   No server-side polling, no parked connection threads.
//! * **Pipelined pooled client** — every request carries a correlation
//!   id; responses may return out of order and a demux reader routes
//!   them back by id. All `StoreClient` handles to one server address
//!   share a single process-global pooled connection (one writer, one
//!   reader), so minting many worlds costs O(servers) sockets, not
//!   O(clients). Dials retry with exponential backoff (1→64 ms).
//! * **Batched verbs** — `MSET`, `MGET` and `WAIT_MANY` move whole key
//!   sets in one round trip; rendezvous exchanges all peer addresses
//!   per world in O(1) round trips regardless of member count, and the
//!   watchdog sweeps all peers' heartbeats with one `MGET` per tick.
//! * **Fault injection** — outgoing requests pass the `store`
//!   pseudo-edge of the chaos plan grammar
//!   (`edge=store:*->* kind=...`, exact-name match only; see
//!   [`crate::mwccl::transport::fault`]), closing the "the watchdog
//!   channel is never injected" gap: delays sleep, drops retransmit
//!   after one RTO, stalls/partitions wedge until healed.
//!
//! ## Protocol (length-prefixed binary, correlation-id pipelined)
//!
//! ```text
//!   request  = id:u64  op:u8  key_len:u32  key  val_len:u32  val
//!   response = id:u64  status:u8  val_len:u32  val
//!   ops: 1=SET 2=GET 3=ADD(val=i64 le) 4=WAIT(timeout ms in val)
//!        5=DELETE 6=COMPARE_SET(val = old_len:u32 old new)
//!        7=KEYS(prefix in key) 8=NUM_KEYS 9=PING
//!        10=MSET(val = count (klen key vlen val)*)
//!        11=MGET(val = count (klen key)*; resp = (present:u8 vlen val)*)
//!        12=WAIT_MANY(val = timeout_ms:u64 count (klen key)*;
//!                     resp Ok = (vlen val)* in request order)
//!   status: 0=ok 1=not_found 2=timeout 3=error
//!   caps: key ≤ 64 KiB, value ≤ 64 MiB (enforced on both ends)
//! ```

mod client;
mod protocol;
mod server;

pub use client::StoreClient;
pub use protocol::{Op, Status, MAX_KEY, MAX_VAL};
pub use server::StoreServer;

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn pair() -> (StoreServer, StoreClient) {
        let server = StoreServer::bind_any().unwrap();
        let client = StoreClient::connect(server.addr(), Duration::from_secs(2)).unwrap();
        (server, client)
    }

    #[test]
    fn set_get_roundtrip() {
        let (_s, c) = pair();
        c.set("alpha", b"1").unwrap();
        assert_eq!(c.get("alpha").unwrap(), Some(b"1".to_vec()));
        assert_eq!(c.get("missing").unwrap(), None);
    }

    #[test]
    fn add_is_atomic_counter() {
        let (_s, c) = pair();
        assert_eq!(c.add("ctr", 5).unwrap(), 5);
        assert_eq!(c.add("ctr", 2).unwrap(), 7);
        assert_eq!(c.add("ctr", -3).unwrap(), 4);
    }

    #[test]
    fn wait_blocks_until_set() {
        let (s, c) = pair();
        let addr = s.addr();
        let setter = std::thread::spawn(move || {
            let c2 = StoreClient::connect(addr, Duration::from_secs(2)).unwrap();
            std::thread::sleep(Duration::from_millis(50));
            c2.set("later", b"v").unwrap();
        });
        let t0 = std::time::Instant::now();
        let v = c.wait("later", Duration::from_secs(2)).unwrap();
        assert_eq!(v, b"v");
        assert!(t0.elapsed() >= Duration::from_millis(40));
        setter.join().unwrap();
    }

    #[test]
    fn wait_times_out() {
        let (_s, c) = pair();
        let err = c.wait("never", Duration::from_millis(80)).unwrap_err();
        assert!(err.to_string().contains("timeout"), "{err}");
    }

    #[test]
    fn delete_and_num_keys() {
        let (_s, c) = pair();
        c.set("a", b"1").unwrap();
        c.set("b", b"2").unwrap();
        assert_eq!(c.num_keys().unwrap(), 2);
        assert!(c.delete("a").unwrap());
        assert!(!c.delete("a").unwrap());
        assert_eq!(c.num_keys().unwrap(), 1);
    }

    #[test]
    fn compare_set_semantics() {
        let (_s, c) = pair();
        c.set("k", b"old").unwrap();
        // Wrong expectation fails and returns current value.
        let cur = c.compare_set("k", b"nope", b"new").unwrap();
        assert_eq!(cur, b"old");
        // Right expectation swaps.
        let cur = c.compare_set("k", b"old", b"new").unwrap();
        assert_eq!(cur, b"new");
        // Empty expectation on a missing key inserts (PyTorch semantics).
        let cur = c.compare_set("fresh", b"", b"init").unwrap();
        assert_eq!(cur, b"init");
    }

    #[test]
    fn keys_by_prefix() {
        let (_s, c) = pair();
        c.set("hb/w1/0", b"1").unwrap();
        c.set("hb/w1/1", b"2").unwrap();
        c.set("addr/0", b"x").unwrap();
        let mut keys = c.keys("hb/w1/").unwrap();
        keys.sort();
        assert_eq!(keys, vec!["hb/w1/0".to_string(), "hb/w1/1".to_string()]);
    }

    #[test]
    fn many_clients_shared_view() {
        let (s, _c) = pair();
        let addr = s.addr();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    let c = StoreClient::connect(addr, Duration::from_secs(2)).unwrap();
                    c.add("shared", i as i64 + 1).unwrap()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let c = StoreClient::connect(addr, Duration::from_secs(2)).unwrap();
        let total: i64 = String::from_utf8(c.get("shared").unwrap().unwrap())
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(total, (1..=8).sum::<i64>());
    }

    #[test]
    fn server_shutdown_breaks_clients() {
        let (s, c) = pair();
        drop(s);
        // Give the acceptor a beat to die.
        std::thread::sleep(Duration::from_millis(30));
        assert!(c.set("x", b"y").is_err() || c.get("x").is_err());
    }

    #[test]
    fn clients_share_one_pooled_conn() {
        let (s, c1) = pair();
        let c2 = StoreClient::connect(s.addr(), Duration::from_secs(2)).unwrap();
        assert!(c1.shares_conn_with(&c2), "same address ⇒ same pooled conn");
        let other = StoreServer::bind_any().unwrap();
        let c3 = StoreClient::connect(other.addr(), Duration::from_secs(2)).unwrap();
        assert!(!c1.shares_conn_with(&c3), "different server ⇒ different conn");
    }

    #[test]
    fn concurrent_adds_on_shared_conn_are_atomic() {
        // All threads share ONE pooled pipelined connection; the adds
        // interleave on the wire but each must apply exactly once.
        let (s, _c) = pair();
        let addr = s.addr();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(move || {
                    let c = StoreClient::connect(addr, Duration::from_secs(2)).unwrap();
                    for _ in 0..50 {
                        c.add("hot", 1).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let c = StoreClient::connect(addr, Duration::from_secs(2)).unwrap();
        assert_eq!(c.add("hot", 0).unwrap(), 400);
    }

    #[test]
    fn mset_mget_roundtrip() {
        let (_s, c) = pair();
        c.mset(&[("m/0", b"a" as &[u8]), ("m/1", b"bb"), ("m/2", b"")]).unwrap();
        let got = c.mget(&["m/0", "m/2", "m/missing", "m/1"]).unwrap();
        assert_eq!(
            got,
            vec![
                Some(b"a".to_vec()),
                Some(Vec::new()),
                None,
                Some(b"bb".to_vec()),
            ]
        );
        // Empty batches are client-side no-ops.
        c.mset(&[]).unwrap();
        assert!(c.mget(&[]).unwrap().is_empty());
    }

    #[test]
    fn wait_many_blocks_until_all_keys_land() {
        let (s, c) = pair();
        let addr = s.addr();
        let setter = std::thread::spawn(move || {
            let c2 = StoreClient::connect(addr, Duration::from_secs(2)).unwrap();
            c2.set("wm/0", b"zero").unwrap();
            std::thread::sleep(Duration::from_millis(60));
            // Last key lands via MSET — the batched write must notify.
            c2.mset(&[("wm/1", b"one" as &[u8]), ("wm/2", b"two")]).unwrap();
        });
        let t0 = std::time::Instant::now();
        let vals = c
            .wait_many(&["wm/0", "wm/1", "wm/2"], Duration::from_secs(2))
            .unwrap();
        assert_eq!(vals, vec![b"zero".to_vec(), b"one".to_vec(), b"two".to_vec()]);
        assert!(t0.elapsed() >= Duration::from_millis(50), "blocked for the mset");
        setter.join().unwrap();
    }

    #[test]
    fn wait_many_times_out_with_partial_keys() {
        let (_s, c) = pair();
        c.set("part/0", b"here").unwrap();
        let t0 = std::time::Instant::now();
        let err = c
            .wait_many(&["part/0", "part/never"], Duration::from_millis(80))
            .unwrap_err();
        assert!(err.to_string().contains("timeout"), "{err}");
        assert!(t0.elapsed() >= Duration::from_millis(70));
        // The present key is untouched and a later wait on it succeeds.
        assert_eq!(c.wait("part/0", Duration::from_millis(100)).unwrap(), b"here");
    }

    #[test]
    fn oversized_keys_and_values_rejected_client_side() {
        let (_s, c) = pair();
        let big_key = "k".repeat(MAX_KEY + 1);
        assert!(c.set(&big_key, b"v").is_err());
        let big_val = vec![0u8; MAX_VAL + 1];
        assert!(c.set("k", &big_val).is_err());
        // The connection survives the rejection (nothing hit the wire).
        c.set("k", b"fine").unwrap();
        assert_eq!(c.get("k").unwrap(), Some(b"fine".to_vec()));
    }

    #[test]
    fn compare_set_races_have_exactly_one_winner_per_key() {
        // 16 threads race empty-expectation compare_set over 4 keys that
        // hash to different shards; exactly one insert wins per key.
        let (s, _c) = pair();
        let addr = s.addr();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                std::thread::spawn(move || {
                    let c = StoreClient::connect(addr, Duration::from_secs(2)).unwrap();
                    let key = format!("race/{}", i % 4);
                    let mine = format!("winner-{i}").into_bytes();
                    let stored = c.compare_set(&key, b"", &mine).unwrap();
                    (key, mine, stored)
                })
            })
            .collect();
        let mut winners: std::collections::HashMap<String, Vec<u8>> = Default::default();
        let mut claimed = 0;
        for h in handles {
            let (key, mine, stored) = h.join().unwrap();
            if stored == mine {
                claimed += 1;
            }
            // Everyone must observe SOME winner's value.
            let w = winners.entry(key).or_insert_with(|| stored.clone());
            assert_eq!(*w, stored, "all racers on a key observe one winner");
        }
        assert_eq!(claimed, 4, "exactly one winner per key");
    }

    #[test]
    fn pipelined_wait_does_not_block_other_ops() {
        // A parked WAIT on the shared connection must not head-of-line
        // block a SET/GET issued afterwards.
        let (s, c) = pair();
        let addr = s.addr();
        let waiter = std::thread::spawn(move || {
            let c2 = StoreClient::connect(addr, Duration::from_secs(2)).unwrap();
            c2.wait("parked", Duration::from_secs(5)).unwrap()
        });
        // Let the WAIT get onto the wire first.
        std::thread::sleep(Duration::from_millis(30));
        let t0 = std::time::Instant::now();
        c.set("other", b"1").unwrap();
        assert_eq!(c.get("other").unwrap(), Some(b"1".to_vec()));
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "ops flowed while the wait was parked"
        );
        c.set("parked", b"released").unwrap();
        assert_eq!(waiter.join().unwrap(), b"released");
    }
}
