//! TCPStore — a PyTorch-compatible-in-spirit blocking key-value store
//! over TCP.
//!
//! PyTorch creates one `TCPStore` per process group during `init`; the
//! paper's watchdog piggybacks worker heartbeats on exactly that store
//! ("It relies on TCPStore created by PyTorch during the initialization
//! of a world. One TCPStore instance is associated with one world.").
//! We reproduce that: the world *leader* hosts a [`StoreServer`]; every
//! member connects a [`StoreClient`]. Rendezvous, rank assignment,
//! address exchange and heartbeats all flow through it.
//!
//! ## Protocol (length-prefixed binary, one request per round trip)
//!
//! ```text
//!   request  = op:u8  key_len:u32  key  val_len:u32  val
//!   response = status:u8  val_len:u32  val
//!   ops: 1=SET 2=GET 3=ADD(val=i64 le) 4=WAIT(timeout ms in val)
//!        5=DELETE 6=COMPARE_SET(val = old_len:u32 old new)
//!        7=KEYS(prefix in key) 8=NUM_KEYS 9=PING
//!   status: 0=ok 1=not_found 2=timeout 3=error
//! ```

mod client;
mod protocol;
mod server;

pub use client::StoreClient;
pub use protocol::{Op, Status};
pub use server::StoreServer;

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn pair() -> (StoreServer, StoreClient) {
        let server = StoreServer::bind_any().unwrap();
        let client = StoreClient::connect(server.addr(), Duration::from_secs(2)).unwrap();
        (server, client)
    }

    #[test]
    fn set_get_roundtrip() {
        let (_s, c) = pair();
        c.set("alpha", b"1").unwrap();
        assert_eq!(c.get("alpha").unwrap(), Some(b"1".to_vec()));
        assert_eq!(c.get("missing").unwrap(), None);
    }

    #[test]
    fn add_is_atomic_counter() {
        let (_s, c) = pair();
        assert_eq!(c.add("ctr", 5).unwrap(), 5);
        assert_eq!(c.add("ctr", 2).unwrap(), 7);
        assert_eq!(c.add("ctr", -3).unwrap(), 4);
    }

    #[test]
    fn wait_blocks_until_set() {
        let (s, c) = pair();
        let addr = s.addr();
        let setter = std::thread::spawn(move || {
            let c2 = StoreClient::connect(addr, Duration::from_secs(2)).unwrap();
            std::thread::sleep(Duration::from_millis(50));
            c2.set("later", b"v").unwrap();
        });
        let t0 = std::time::Instant::now();
        let v = c.wait("later", Duration::from_secs(2)).unwrap();
        assert_eq!(v, b"v");
        assert!(t0.elapsed() >= Duration::from_millis(40));
        setter.join().unwrap();
    }

    #[test]
    fn wait_times_out() {
        let (_s, c) = pair();
        let err = c.wait("never", Duration::from_millis(80)).unwrap_err();
        assert!(err.to_string().contains("timeout"), "{err}");
    }

    #[test]
    fn delete_and_num_keys() {
        let (_s, c) = pair();
        c.set("a", b"1").unwrap();
        c.set("b", b"2").unwrap();
        assert_eq!(c.num_keys().unwrap(), 2);
        assert!(c.delete("a").unwrap());
        assert!(!c.delete("a").unwrap());
        assert_eq!(c.num_keys().unwrap(), 1);
    }

    #[test]
    fn compare_set_semantics() {
        let (_s, c) = pair();
        c.set("k", b"old").unwrap();
        // Wrong expectation fails and returns current value.
        let cur = c.compare_set("k", b"nope", b"new").unwrap();
        assert_eq!(cur, b"old");
        // Right expectation swaps.
        let cur = c.compare_set("k", b"old", b"new").unwrap();
        assert_eq!(cur, b"new");
        // Empty expectation on a missing key inserts (PyTorch semantics).
        let cur = c.compare_set("fresh", b"", b"init").unwrap();
        assert_eq!(cur, b"init");
    }

    #[test]
    fn keys_by_prefix() {
        let (_s, c) = pair();
        c.set("hb/w1/0", b"1").unwrap();
        c.set("hb/w1/1", b"2").unwrap();
        c.set("addr/0", b"x").unwrap();
        let mut keys = c.keys("hb/w1/").unwrap();
        keys.sort();
        assert_eq!(keys, vec!["hb/w1/0".to_string(), "hb/w1/1".to_string()]);
    }

    #[test]
    fn many_clients_shared_view() {
        let (s, _c) = pair();
        let addr = s.addr();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    let c = StoreClient::connect(addr, Duration::from_secs(2)).unwrap();
                    c.add("shared", i as i64 + 1).unwrap()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let c = StoreClient::connect(addr, Duration::from_secs(2)).unwrap();
        let total: i64 = String::from_utf8(c.get("shared").unwrap().unwrap())
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(total, (1..=8).sum::<i64>());
    }

    #[test]
    fn server_shutdown_breaks_clients() {
        let (s, c) = pair();
        drop(s);
        // Give the acceptor a beat to die.
        std::thread::sleep(Duration::from_millis(30));
        assert!(c.set("x", b"y").is_err() || c.get("x").is_err());
    }
}
