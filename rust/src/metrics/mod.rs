//! Lightweight metrics: counters, gauges, histograms, a sliding-window
//! quantile tracker (the serving autoscaler's recent-latency signal)
//! and a timestamped timeline recorder used to regenerate the paper's
//! time-series figures (Figs 4 and 5). Also hosts the process-wide
//! [`global`] registry and the [`log_event`] structured log line, so
//! daemons without an injected registry (e.g. the MultiWorld watchdog)
//! stay observable in benches and CI logs.

use crate::util::time::since_epoch;
use once_cell::sync::Lazy;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic counter.
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous value.
#[derive(Default, Debug)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Latency histogram with exponential buckets from 1µs to ~68s plus a
/// running sum/count for exact means.
#[derive(Debug)]
pub struct Histogram {
    /// bucket i counts samples in [2^i µs, 2^(i+1) µs).
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const HIST_BUCKETS: usize = 27; // 2^26 µs ≈ 67 s

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, dur: std::time::Duration) {
        self.observe_us(dur.as_micros() as u64);
    }

    pub fn observe_us(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(HIST_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-th sample).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us()
    }
}

/// Latency samples over a sliding wall-clock window — the autoscaler's
/// *recent* p99 signal. The cumulative [`Histogram`] never forgets, so
/// a long-healthy run would mask a fresh SLO breach (and a past breach
/// would mask recovery); this window does not. Samples are pruned on
/// every observe/read, so memory is bounded by the arrival rate times
/// the window.
#[derive(Debug)]
pub struct SlidingWindow {
    window: std::time::Duration,
    samples: Mutex<std::collections::VecDeque<(std::time::Instant, u64)>>,
}

impl SlidingWindow {
    pub fn new(window: std::time::Duration) -> Self {
        SlidingWindow {
            window,
            samples: Mutex::new(std::collections::VecDeque::new()),
        }
    }

    fn prune(
        &self,
        samples: &mut std::collections::VecDeque<(std::time::Instant, u64)>,
    ) {
        let now = std::time::Instant::now();
        while let Some(&(t, _)) = samples.front() {
            if now.duration_since(t) > self.window {
                samples.pop_front();
            } else {
                break;
            }
        }
    }

    pub fn observe_us(&self, us: u64) {
        let mut s = self.samples.lock().unwrap();
        self.prune(&mut s);
        s.push_back((std::time::Instant::now(), us));
    }

    pub fn observe(&self, dur: std::time::Duration) {
        self.observe_us(dur.as_micros() as u64);
    }

    /// Samples currently inside the window.
    pub fn count(&self) -> usize {
        let mut s = self.samples.lock().unwrap();
        self.prune(&mut s);
        s.len()
    }

    /// Exact quantile over the window (0 when empty).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let mut s = self.samples.lock().unwrap();
        self.prune(&mut s);
        if s.is_empty() {
            return 0;
        }
        let mut vals: Vec<u64> = s.iter().map(|&(_, v)| v).collect();
        vals.sort_unstable();
        let idx = ((vals.len() as f64 * q).ceil() as usize)
            .saturating_sub(1)
            .min(vals.len() - 1);
        vals[idx]
    }
}

/// A single named registry shared across a process.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
    windows: Mutex<BTreeMap<String, Arc<SlidingWindow>>>,
}

impl Registry {
    pub fn new() -> Arc<Self> {
        Arc::new(Registry::default())
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Named sliding window (samples age out after 60 s) — the recency
    /// twin of [`Registry::histogram`], for signals where only the
    /// recent distribution matters (e.g. `serving.mttr_ms`).
    pub fn window(&self, name: &str) -> Arc<SlidingWindow> {
        self.windows
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| {
                Arc::new(SlidingWindow::new(std::time::Duration::from_secs(60)))
            })
            .clone()
    }

    /// Text dump, one metric per line (sorted, stable for tests).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} {}\n", v.get()));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("gauge {k} {}\n", v.get()));
        }
        for (k, v) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "hist {k} count={} mean_us={:.1} p50_us={} p99_us={} max_us={}\n",
                v.count(),
                v.mean_us(),
                v.quantile_us(0.50),
                v.quantile_us(0.99),
                v.max_us()
            ));
        }
        for (k, v) in self.windows.lock().unwrap().iter() {
            out.push_str(&format!(
                "window {k} count={} p50_us={} p99_us={}\n",
                v.count(),
                v.quantile_us(0.50),
                v.quantile_us(0.99)
            ));
        }
        out
    }
}

/// The process-wide registry, for components that outlive or predate
/// any injected one (the watchdog daemon, transports, CLI tools).
/// Renderable alongside per-experiment registries via
/// [`Registry::render`].
pub fn global() -> &'static Registry {
    static GLOBAL: Lazy<Registry> = Lazy::new(Registry::default);
    &GLOBAL
}

/// Emit one structured event line to stderr:
/// `[mw] event=<name> key=value …` — greppable in bench output and CI
/// logs (values containing whitespace are quoted). This is the logging
/// half of an observable event; pair it with a [`global`] counter for
/// the countable half.
pub fn log_event(event: &str, fields: &[(&str, &str)]) {
    let mut line = format!("[mw] event={event}");
    for (k, v) in fields {
        if v.chars().any(|c| c.is_whitespace()) {
            line.push_str(&format!(" {k}={v:?}"));
        } else {
            line.push_str(&format!(" {k}={v}"));
        }
    }
    eprintln!("{line}");
}

/// One timestamped event in an experiment timeline.
#[derive(Clone, Debug, PartialEq)]
pub struct TimelinePoint {
    /// Seconds since the experiment epoch.
    pub t: f64,
    /// Series name ("W1-R1", "W2-R1", …) — the paper labels series by
    /// world-rank identifiers.
    pub series: String,
    /// Value (GB/s for throughput plots, 1.0 for event markers).
    pub value: f64,
    /// Optional annotation ("join", "failure detected", …).
    pub label: String,
}

/// Records (t, series, value) points; dumps CSV that the bench harness
/// prints for the timeline figures.
#[derive(Default, Clone)]
pub struct Timeline {
    points: Arc<Mutex<Vec<TimelinePoint>>>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, series: &str, value: f64) {
        self.record_at(since_epoch(), series, value, "");
    }

    pub fn record_labeled(&self, series: &str, value: f64, label: &str) {
        self.record_at(since_epoch(), series, value, label);
    }

    pub fn record_at(&self, t: f64, series: &str, value: f64, label: &str) {
        self.points.lock().unwrap().push(TimelinePoint {
            t,
            series: series.to_string(),
            value,
            label: label.to_string(),
        });
    }

    pub fn points(&self) -> Vec<TimelinePoint> {
        self.points.lock().unwrap().clone()
    }

    /// Points for one series, ordered by time.
    pub fn series(&self, name: &str) -> Vec<TimelinePoint> {
        let mut v: Vec<_> = self
            .points
            .lock()
            .unwrap()
            .iter()
            .filter(|p| p.series == name)
            .cloned()
            .collect();
        v.sort_by(|a, b| a.t.total_cmp(&b.t));
        v
    }

    /// CSV dump: `t,series,value,label`.
    pub fn to_csv(&self) -> String {
        let mut points = self.points();
        points.sort_by(|a, b| a.t.total_cmp(&b.t));
        let mut s = String::from("t_sec,series,value,label\n");
        for p in points {
            s.push_str(&format!("{:.3},{},{:.6},{}\n", p.t, p.series, p.value, p.label));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::new();
        r.counter("reqs").inc();
        r.counter("reqs").add(4);
        assert_eq!(r.counter("reqs").get(), 5);
        r.gauge("depth").set(7);
        r.gauge("depth").add(-2);
        assert_eq!(r.gauge("depth").get(), 5);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::default();
        for us in [10u64, 100, 1_000, 10_000, 100_000] {
            for _ in 0..20 {
                h.observe_us(us);
            }
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p99);
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 100_000);
    }

    #[test]
    fn histogram_observe_duration() {
        let h = Histogram::default();
        h.observe(Duration::from_millis(3));
        assert_eq!(h.count(), 1);
        assert!(h.mean_us() >= 3000.0);
    }

    #[test]
    fn timeline_series_sorted() {
        let tl = Timeline::new();
        tl.record_at(2.0, "W1-R1", 10.0, "");
        tl.record_at(1.0, "W1-R1", 5.0, "");
        tl.record_at(1.5, "W2-R1", 7.0, "join");
        let s = tl.series("W1-R1");
        assert_eq!(s.len(), 2);
        assert!(s[0].t < s[1].t);
        let csv = tl.to_csv();
        assert!(csv.starts_with("t_sec,series,value,label\n"));
        assert!(csv.contains("W2-R1"));
        assert!(csv.contains("join"));
    }

    #[test]
    fn sliding_window_quantiles_and_expiry() {
        let w = SlidingWindow::new(Duration::from_millis(60));
        for us in [100u64, 200, 300, 400] {
            w.observe_us(us);
        }
        assert_eq!(w.count(), 4);
        assert_eq!(w.quantile_us(0.5), 200);
        assert_eq!(w.quantile_us(0.99), 400);
        std::thread::sleep(Duration::from_millis(80));
        assert_eq!(w.count(), 0, "samples age out of the window");
        assert_eq!(w.quantile_us(0.99), 0);
        w.observe(Duration::from_millis(1));
        assert_eq!(w.quantile_us(0.99), 1_000);
    }

    #[test]
    fn global_registry_is_shared() {
        let before = global().counter("test.global_shared").get();
        global().counter("test.global_shared").inc();
        assert_eq!(global().counter("test.global_shared").get(), before + 1);
    }

    #[test]
    fn log_event_does_not_panic() {
        log_event("test.event", &[("plain", "v"), ("spaced", "a b")]);
    }

    #[test]
    fn registry_render_stable() {
        let r = Registry::new();
        r.counter("b").inc();
        r.counter("a").inc();
        let out = r.render();
        let a = out.find("counter a").unwrap();
        let b = out.find("counter b").unwrap();
        assert!(a < b);
    }
}
