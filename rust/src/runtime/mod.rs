//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them
//! from the Rust request path (python is build-time only).
//!
//! The real engine (see `engine.rs`) wraps the `xla` crate and is gated
//! behind the **`pjrt`** cargo feature so the core serving/CCL stack
//! builds and tests fully offline. Without the feature, a stub with the
//! same API surface is compiled: constructors return a descriptive
//! error, and the integration tests that need compiled artifacts skip
//! themselves (they already probe for `artifacts/model.json`).

#[cfg(feature = "pjrt")]
mod engine;
#[cfg(feature = "pjrt")]
pub use engine::*;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::*;

/// Default artifacts directory: `$MW_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("MW_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
