//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them
//! from the Rust request path (python is build-time only).
//!
//! The real engine (see `engine.rs`) wraps the `xla` crate and is gated
//! behind the **`pjrt` + `xla-backend`** cargo features so the core
//! serving/CCL stack builds and tests fully offline. With `pjrt` alone
//! — or neither — a stub with the same API surface is compiled:
//! constructors return a descriptive error, and the integration tests
//! that need compiled artifacts skip themselves (they already probe for
//! `artifacts/model.json`). CI builds `--features pjrt` against the
//! stub on every push, so the feature-gated call sites cannot rot while
//! the `xla` dependency waits on an artifacts cache (see ROADMAP).

/// Slot-addressed per-request decode state for continuous batching —
/// pure bookkeeping, shared by the real engine and the stub.
pub mod decode;

#[cfg(all(feature = "pjrt", feature = "xla-backend"))]
mod engine;
#[cfg(all(feature = "pjrt", feature = "xla-backend"))]
pub use engine::*;

#[cfg(not(all(feature = "pjrt", feature = "xla-backend")))]
mod stub;
#[cfg(not(all(feature = "pjrt", feature = "xla-backend")))]
pub use stub::*;

/// Default artifacts directory: `$MW_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("MW_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
