//! API-compatible stand-in for the PJRT engine when the `pjrt` feature
//! (and thus the `xla` crate) is disabled. Every entry point that would
//! execute a model returns a descriptive error instead; the rest of the
//! stack (CCL, serving, launch) compiles and tests unchanged.

use crate::config::{ModelManifest, StageSpec};
use crate::tensor::Tensor;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

const NO_PJRT: &str =
    "built without the 'pjrt' feature: PJRT execution unavailable (rebuild with --features pjrt)";

/// Stub of the PJRT CPU client wrapper.
pub struct Engine {
    _private: (),
}

impl Engine {
    pub fn cpu() -> anyhow::Result<Arc<Engine>> {
        anyhow::bail!(NO_PJRT)
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load_stage(
        self: &Arc<Self>,
        _hlo_path: &Path,
        _spec: &StageSpec,
    ) -> anyhow::Result<StageRunner> {
        anyhow::bail!(NO_PJRT)
    }
}

/// Stub of one compiled pipeline stage.
pub struct StageRunner {
    spec: StageSpec,
    /// Execution latency histogram (µs) — kept for API parity.
    pub exec_time: crate::metrics::Histogram,
}

impl StageRunner {
    pub fn spec(&self) -> &StageSpec {
        &self.spec
    }

    pub fn run(&self, _input: &Tensor) -> anyhow::Result<Tensor> {
        anyhow::bail!(NO_PJRT)
    }

    /// Stub of the tensor-parallel execution path (see the engine's
    /// `run_sharded`): validates the shard coordinates, then reports the
    /// missing backend like every other entry point.
    pub fn run_sharded(&self, _input: &Tensor, shard: usize, tp: usize) -> anyhow::Result<Tensor> {
        anyhow::ensure!(tp >= 1 && shard < tp, "shard {shard} out of range for tp {tp}");
        anyhow::bail!(NO_PJRT)
    }

    /// Stub of the step-wise decode entry point (see the engine's
    /// `decode_step`): the slot bookkeeping is real and shared, only the
    /// stage execution is missing.
    pub fn decode_step(
        &self,
        _slots: &mut crate::runtime::decode::DecodeSlots,
        _input: &Tensor,
    ) -> anyhow::Result<Tensor> {
        anyhow::bail!(NO_PJRT)
    }

    pub fn mean_exec(&self) -> Duration {
        Duration::from_micros(self.exec_time.mean_us() as u64)
    }
}

/// Stub of the loaded model (all stages + monolith).
pub struct ModelRuntime {
    pub manifest: ModelManifest,
    pub stages: Vec<Arc<StageRunner>>,
    pub full: Option<StageRunner>,
}

impl ModelRuntime {
    pub fn load(_artifacts_dir: impl AsRef<Path>) -> anyhow::Result<ModelRuntime> {
        anyhow::bail!(NO_PJRT)
    }

    pub fn run_pipeline(&self, _tokens: &Tensor) -> anyhow::Result<Tensor> {
        anyhow::bail!(NO_PJRT)
    }

    pub fn verify_golden(&self, _artifacts_dir: impl AsRef<Path>) -> anyhow::Result<()> {
        anyhow::bail!(NO_PJRT)
    }
}
