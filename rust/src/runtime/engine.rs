//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them
//! from the Rust request path (python is build-time only).
//!
//! Flow per stage: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! HLO *text* is the interchange format (see `python/compile/aot.py`);
//! the lowered functions were built with `return_tuple=True`, so every
//! output is a 1-tuple unwrapped with `to_tuple1`.

use crate::config::{ModelManifest, StageSpec};
use crate::tensor::{DType, Tensor};
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

/// Wrapper around one PJRT CPU client. Create once per process; stages
/// share it.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> anyhow::Result<Arc<Engine>> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Arc::new(Engine { client }))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text file into a runnable stage.
    pub fn load_stage(
        self: &Arc<Self>,
        hlo_path: &Path,
        spec: &StageSpec,
    ) -> anyhow::Result<StageRunner> {
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(StageRunner {
            engine: self.clone(),
            exe,
            spec: spec.clone(),
            exec_time: crate::metrics::Histogram::default(),
        })
    }
}

fn element_type(d: DType) -> xla::ElementType {
    match d {
        DType::F32 => xla::ElementType::F32,
        DType::BF16 => xla::ElementType::Bf16,
        DType::I32 => xla::ElementType::S32,
        DType::U8 => xla::ElementType::U8,
    }
}

/// Convert a coordinator [`Tensor`] into an XLA literal (zero parse, one
/// memcpy inside XLA).
pub fn tensor_to_literal(t: &Tensor) -> anyhow::Result<xla::Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        element_type(t.dtype()),
        t.shape(),
        t.bytes(),
    )?)
}

/// Convert an XLA literal back into a [`Tensor`].
pub fn literal_to_tensor(
    lit: &xla::Literal,
    dtype: DType,
    shape: &[usize],
) -> anyhow::Result<Tensor> {
    let mut out = Tensor::zeros(dtype, shape);
    match dtype {
        DType::F32 => {
            let v: Vec<f32> = lit.to_vec()?;
            anyhow::ensure!(v.len() == out.elems(), "literal size mismatch");
            out.bytes_mut().copy_from_slice(unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            });
        }
        DType::I32 => {
            let v: Vec<i32> = lit.to_vec()?;
            anyhow::ensure!(v.len() == out.elems(), "literal size mismatch");
            out.bytes_mut().copy_from_slice(unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            });
        }
        other => anyhow::bail!("literal_to_tensor: unsupported dtype {other:?}"),
    }
    Ok(out)
}

/// One compiled pipeline stage.
pub struct StageRunner {
    #[allow(dead_code)]
    engine: Arc<Engine>,
    exe: xla::PjRtLoadedExecutable,
    spec: StageSpec,
    /// Execution latency histogram (µs).
    pub exec_time: crate::metrics::Histogram,
}

impl StageRunner {
    pub fn spec(&self) -> &StageSpec {
        &self.spec
    }

    /// Run the stage on one input tensor; validates shapes both ways.
    pub fn run(&self, input: &Tensor) -> anyhow::Result<Tensor> {
        anyhow::ensure!(
            input.shape() == self.spec.in_shape.as_slice(),
            "stage {} expects {:?}, got {:?}",
            self.spec.name,
            self.spec.in_shape,
            input.shape()
        );
        anyhow::ensure!(
            input.dtype() == self.spec.in_dtype,
            "stage {} expects {:?}, got {:?}",
            self.spec.name,
            self.spec.in_dtype,
            input.dtype()
        );
        let t0 = std::time::Instant::now();
        let lit = tensor_to_literal(input)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let tensor = literal_to_tensor(&out, self.spec.out_dtype, &self.spec.out_shape)?;
        self.exec_time.observe(t0.elapsed());
        Ok(tensor)
    }

    /// Tensor-parallel execution: shard `shard` of `tp` computes its
    /// slice of the stage; the partial outputs of all shards sum
    /// (all_reduce) to the full stage output — the row-parallel combine
    /// contract. The AOT artifact is a fused whole-stage executable, so
    /// the reproduction runs it whole and scales the output by `1/tp`:
    /// `Σ_shards out/tp == out` holds exactly for power-of-two `tp`, and
    /// the communication volume per combine matches real weight-sharded
    /// execution even though compute is replicated per shard.
    pub fn run_sharded(&self, input: &Tensor, shard: usize, tp: usize) -> anyhow::Result<Tensor> {
        anyhow::ensure!(tp >= 1 && shard < tp, "shard {shard} out of range for tp {tp}");
        let mut out = self.run(input)?;
        if tp > 1 {
            anyhow::ensure!(
                out.dtype() == DType::F32,
                "stage {}: sharded execution needs f32 outputs",
                self.spec.name
            );
            out.scale(1.0 / tp as f32);
        }
        Ok(out)
    }

    /// One decode iteration over the running batch: run the stage on
    /// the step input (rows = slots, whether occupied or padding) and
    /// advance every occupied slot's position/budget. The AOT artifact
    /// is a fixed-shape whole-sequence executable, so a step re-runs it
    /// on the slot-packed input — compute is not incremental, but the
    /// slot lifecycle (alloc at prefill, advance per step, free at
    /// retire) is exactly the paged-KV contract a step-wise kernel
    /// would see.
    pub fn decode_step(
        &self,
        slots: &mut crate::runtime::decode::DecodeSlots,
        input: &Tensor,
    ) -> anyhow::Result<Tensor> {
        let out = self.run(input)?;
        slots.advance();
        Ok(out)
    }

    /// Mean execution latency so far.
    pub fn mean_exec(&self) -> Duration {
        Duration::from_micros(self.exec_time.mean_us() as u64)
    }
}

/// All stages of a model, plus the monolithic fallback executable.
pub struct ModelRuntime {
    pub manifest: ModelManifest,
    pub stages: Vec<Arc<StageRunner>>,
    pub full: Option<StageRunner>,
}

impl ModelRuntime {
    /// Load every stage listed in `artifacts/model.json`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<ModelRuntime> {
        let dir = artifacts_dir.as_ref();
        let manifest = ModelManifest::load(dir.join("model.json"))?;
        let engine = Engine::cpu()?;
        let mut stages = Vec::with_capacity(manifest.stages.len());
        for spec in &manifest.stages {
            stages.push(Arc::new(engine.load_stage(&manifest.hlo_path(spec), spec)?));
        }
        // The monolith runs tokens→logits in one call.
        let full_path = dir.join("full_model.hlo.txt");
        let full = if full_path.exists() {
            let spec = StageSpec {
                name: "full_model".into(),
                hlo: full_path.clone(),
                in_shape: manifest.stages[0].in_shape.clone(),
                out_shape: manifest.stages.last().unwrap().out_shape.clone(),
                in_dtype: manifest.stages[0].in_dtype,
                out_dtype: manifest.stages.last().unwrap().out_dtype,
                params: manifest.total_params(),
            };
            Some(engine.load_stage(&full_path, &spec)?)
        } else {
            None
        };
        Ok(ModelRuntime { manifest, stages, full })
    }

    /// Run the full pipeline stage by stage (in one process — the
    /// distributed path shards these stages across workers).
    pub fn run_pipeline(&self, tokens: &Tensor) -> anyhow::Result<Tensor> {
        let mut x = tokens.clone();
        for stage in &self.stages {
            x = stage.run(&x)?;
        }
        Ok(x)
    }

    /// Verify stage composition and the monolith against the golden
    /// input/output pair emitted by `aot.py` — the end-to-end numerics
    /// proof that the Rust path reproduces JAX exactly.
    pub fn verify_golden(&self, artifacts_dir: impl AsRef<Path>) -> anyhow::Result<()> {
        let text = std::fs::read_to_string(artifacts_dir.as_ref().join("golden.json"))?;
        let j = crate::util::json::Json::parse(&text)?;
        let tokens_shape: Vec<usize> = j
            .get("tokens_shape")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .ok_or_else(|| anyhow::anyhow!("golden missing tokens_shape"))?;
        let tokens: Vec<i32> = j
            .get("tokens")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_i64()).map(|x| x as i32).collect())
            .ok_or_else(|| anyhow::anyhow!("golden missing tokens"))?;
        let expect_sample: Vec<f64> = j
            .get("logits_sample")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
            .ok_or_else(|| anyhow::anyhow!("golden missing logits_sample"))?;
        let expect_checksum = j
            .get("logits_checksum")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("golden missing logits_checksum"))?;

        let input = Tensor::from_i32(&tokens_shape, &tokens);
        let logits = self.run_pipeline(&input)?;
        let got = logits.as_f32();
        for (i, &e) in expect_sample.iter().enumerate() {
            let g = got[i] as f64;
            anyhow::ensure!(
                (g - e).abs() <= 1e-4 * (1.0 + e.abs()),
                "logit[{i}]: rust {g} vs jax {e}"
            );
        }
        let checksum: f64 = got.iter().map(|x| x.abs() as f64).sum();
        anyhow::ensure!(
            (checksum - expect_checksum).abs() <= 1e-3 * (1.0 + expect_checksum.abs()),
            "|logits| sum: rust {checksum} vs jax {expect_checksum}"
        );
        // Monolith agrees with the stage pipeline.
        if let Some(full) = &self.full {
            let mono = full.run(&input)?;
            anyhow::ensure!(
                mono.as_f32()
                    .iter()
                    .zip(logits.as_f32())
                    .all(|(a, b)| (a - b).abs() <= 1e-4 * (1.0 + b.abs())),
                "monolith and pipeline disagree"
            );
        }
        Ok(())
    }
}
