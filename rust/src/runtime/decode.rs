//! Slot-addressed per-request decode state for the continuous batching
//! loop — shared by the real PJRT engine and the stub (it is pure
//! bookkeeping, no backend calls).
//!
//! Iteration-level scheduling keeps a *running batch* resident in each
//! stage worker: every decode step admits new requests into free slots
//! (prefill) and retires finished ones, instead of gang-scheduling a
//! fixed batch to completion. The state that must live worker-side for
//! that to work — which request owns which slot, its decode position,
//! its remaining token budget, and a handle to its KV-cache allocation
//! — is exactly what [`DecodeSlots`] tracks.
//!
//! Ownership contract: the **leader is the source of truth**. Workers
//! apply the slot directives carried by each step frame idempotently
//! (`alloc` twice is fine, `free` of an empty slot is fine, a directive
//! that disagrees with local state *adopts* the leader's view). That
//! makes worker state soft: a promoted spare starts from empty slots
//! and the very next step frame re-prefills whatever the leader still
//! considers in flight — lost KV state means re-prefill, never lost
//! requests.

/// Per-slot decode state for one resident request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SlotState {
    /// Owning request id (leader-assigned).
    pub req_id: u64,
    /// Decode position: tokens generated so far for this request.
    pub pos: u32,
    /// Remaining token budget (decrements per decode step; the leader
    /// retires the request when it hits zero).
    pub budget: u32,
    /// Opaque KV-cache handle. The reproduction's AOT stages are
    /// stateless, so this is a synthesized allocation tag rather than a
    /// device pointer — but it flows through alloc/free exactly where a
    /// real paged-KV handle would, so the lifecycle is load-bearing.
    pub kv: u64,
}

/// A stage worker's running batch: `capacity` slots, each either free or
/// owned by one in-flight request. Grows on demand if the leader ever
/// addresses a slot beyond the initial capacity (e.g. after a config
/// change), so a stale worker can always adopt the leader's view.
#[derive(Default)]
pub struct DecodeSlots {
    slots: Vec<Option<SlotState>>,
    /// Monotonic KV allocation tag source.
    next_kv: u64,
}

impl DecodeSlots {
    pub fn new(capacity: usize) -> Self {
        DecodeSlots { slots: vec![None; capacity], next_kv: 1 }
    }

    fn ensure(&mut self, slot: usize) {
        if slot >= self.slots.len() {
            self.slots.resize(slot + 1, None);
        }
    }

    /// Idempotent prefill-time allocation: bind `slot` to `req_id` at
    /// position `pos` with `budget` tokens remaining. If the slot
    /// already holds this request the call is a no-op (duplicate step
    /// frame after a retry); if it holds a *different* request the
    /// leader's view wins and the old occupant's KV is released.
    /// Returns the slot's KV handle.
    pub fn alloc(&mut self, slot: usize, req_id: u64, pos: u32, budget: u32) -> u64 {
        self.ensure(slot);
        if let Some(st) = &self.slots[slot] {
            if st.req_id == req_id {
                return st.kv;
            }
        }
        let kv = self.next_kv;
        self.next_kv += 1;
        self.slots[slot] = Some(SlotState { req_id, pos, budget, kv });
        kv
    }

    /// Adopt the leader's view of a decoding slot: same request advances
    /// in place; an unknown or different request (this worker was just
    /// promoted, or a retry raced a retirement) is treated as a fresh
    /// allocation.
    pub fn adopt(&mut self, slot: usize, req_id: u64, pos: u32, budget: u32) {
        self.ensure(slot);
        match &mut self.slots[slot] {
            Some(st) if st.req_id == req_id => {
                st.pos = pos;
                st.budget = budget;
            }
            _ => {
                self.alloc(slot, req_id, pos, budget);
            }
        }
    }

    /// Free a slot (request retired). Idempotent: freeing an empty slot
    /// is a no-op.
    pub fn free(&mut self, slot: usize) {
        if slot < self.slots.len() {
            self.slots[slot] = None;
        }
    }

    /// Advance every occupied slot by one decode step: position up,
    /// budget down (saturating). Called once per executed iteration.
    pub fn advance(&mut self) {
        for st in self.slots.iter_mut().flatten() {
            st.pos += 1;
            st.budget = st.budget.saturating_sub(1);
        }
    }

    /// The state at `slot`, if occupied.
    pub fn get(&self, slot: usize) -> Option<&SlotState> {
        self.slots.get(slot).and_then(|s| s.as_ref())
    }

    /// Number of occupied slots (the running batch size).
    pub fn occupied(&self) -> usize {
        self.slots.iter().flatten().count()
    }

    /// Total slot capacity.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Drop all state (worker shutdown / world re-mint).
    pub fn clear(&mut self) {
        for s in self.slots.iter_mut() {
            *s = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_lifecycle() {
        let mut s = DecodeSlots::new(4);
        assert_eq!(s.capacity(), 4);
        assert_eq!(s.occupied(), 0);
        let kv = s.alloc(1, 100, 0, 8);
        assert_eq!(s.occupied(), 1);
        assert_eq!(s.get(1).unwrap(), &SlotState { req_id: 100, pos: 0, budget: 8, kv });
        s.free(1);
        assert!(s.get(1).is_none());
        assert_eq!(s.occupied(), 0);
        s.free(1); // idempotent
        s.free(99); // out of range is a no-op
    }

    #[test]
    fn alloc_is_idempotent_per_request_but_replaces_strangers() {
        let mut s = DecodeSlots::new(2);
        let kv1 = s.alloc(0, 7, 0, 4);
        let kv_again = s.alloc(0, 7, 0, 4);
        assert_eq!(kv1, kv_again, "duplicate frame reuses the KV handle");
        let kv2 = s.alloc(0, 8, 0, 4);
        assert_ne!(kv1, kv2, "leader reassigned the slot: fresh KV");
        assert_eq!(s.get(0).unwrap().req_id, 8);
    }

    #[test]
    fn adopt_advances_own_request_and_takes_over_unknown() {
        let mut s = DecodeSlots::new(2);
        let kv = s.alloc(0, 7, 0, 4);
        s.adopt(0, 7, 2, 2);
        let st = s.get(0).unwrap();
        assert_eq!((st.pos, st.budget, st.kv), (2, 2, kv), "in-place advance keeps KV");
        // A just-promoted worker has nothing at slot 1 — adopting the
        // leader's decode directive re-prefills it.
        s.adopt(1, 9, 3, 1);
        assert_eq!(s.get(1).unwrap().req_id, 9);
        assert_eq!(s.get(1).unwrap().pos, 3);
    }

    #[test]
    fn advance_moves_every_occupant() {
        let mut s = DecodeSlots::new(3);
        s.alloc(0, 1, 0, 2);
        s.alloc(2, 2, 5, 1);
        s.advance();
        assert_eq!((s.get(0).unwrap().pos, s.get(0).unwrap().budget), (1, 1));
        assert_eq!((s.get(2).unwrap().pos, s.get(2).unwrap().budget), (6, 0));
        s.advance();
        assert_eq!(s.get(2).unwrap().budget, 0, "budget saturates at zero");
    }

    #[test]
    fn grows_on_demand_and_clears() {
        let mut s = DecodeSlots::new(1);
        s.alloc(5, 42, 0, 1);
        assert!(s.capacity() >= 6, "slot addressing beyond capacity grows");
        assert_eq!(s.get(5).unwrap().req_id, 42);
        s.clear();
        assert_eq!(s.occupied(), 0);
        assert!(s.capacity() >= 6, "clear keeps capacity");
    }
}
