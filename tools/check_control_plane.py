#!/usr/bin/env python3
"""Control-plane minting-throughput regression gate over
BENCH_control_plane.json.

Reads the `control_plane` section the scaled `fig5_online_instantiation`
bench emits — N two-rank worlds minted concurrently through the sharded
store + batched rendezvous, with worlds/s and the store-op cost per
world — and checks it two ways:

  * **batching efficacy**: store ops per world must stay O(1) in the
    member count (publish + collect + barrier ≈ 4 per member + 1, so
    ~9 for a two-rank world); a jump back toward per-peer wait chains
    shows up here long before wall-clock does;
  * **regression vs baseline**: worlds/s is compared against the
    committed `tools/control_plane_baseline.json`; a measurement more
    than --tolerance-pct slower than baseline (default 25%) is flagged.

Both checks are *soft* failures, matching check_mttr.py: the script
prints GitHub Actions `::warning::` annotations and always exits 0 —
minting throughput on a shared CI box is noisy (thread scheduling,
ephemeral-port churn), so a hard gate would flake. The warnings make
every drift visible on the push that caused it.

The artifact's `meta` block (commit / branch / run / knobs) is printed
for provenance and skipped as data. Re-baseline by copying the measured
worlds/s from a healthy run into tools/control_plane_baseline.json.
"""

import argparse
import json
import sys


def warn(msg: str) -> None:
    print(f"::warning title=control-plane::{msg}")


def load(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        warn(f"cannot read {path}: {e}")
        return None


def print_meta(doc: dict) -> None:
    meta = doc.get("meta")
    if not isinstance(meta, dict):
        print("(artifact has no meta block)")
        return
    sha = meta.get("sha") or "?"
    branch = meta.get("branch") or "?"
    run = meta.get("run_id") or "local"
    cfg = " ".join(f"{k}={v}" for k, v in sorted(meta.get("config", {}).items()))
    print(f"provenance: {sha[:12]} ({branch}, run {run}) {cfg}".rstrip())


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifact", help="path to BENCH_control_plane.json")
    ap.add_argument("--baseline", default="tools/control_plane_baseline.json",
                    help="committed minting baseline (default "
                         "tools/control_plane_baseline.json)")
    ap.add_argument("--tolerance-pct", type=float, default=25.0,
                    help="regression threshold vs baseline, percent "
                         "(default 25)")
    args = ap.parse_args()

    doc = load(args.artifact)
    if doc is None:
        return 0
    print_meta(doc)
    cp = doc.get("control_plane")
    if not isinstance(cp, dict):
        warn(f"{args.artifact} has no control_plane section — did the "
             f"fig5_online_instantiation bench run?")
        return 0

    warnings = 0
    worlds = cp.get("worlds") or 0
    wps = cp.get("worlds_per_s")
    opw = cp.get("store_ops_per_world")
    print(f"minted {worlds:.0f} worlds across {cp.get('threads', 0):.0f} "
          f"threads in {cp.get('secs', 0):.2f} s")

    # ---- batching efficacy: O(1) store ops per world ------------------
    if opw is None:
        warnings += 1
        warn("artifact has no store_ops_per_world — op accounting broken?")
    elif opw > 12.0:
        warnings += 1
        warn(f"store ops per minted world is {opw:.1f} (expected ~9 for a "
             f"two-rank world) — the batched rendezvous may have "
             f"regressed toward per-peer round trips")
    else:
        print(f"store ops per world: {opw:.1f} ok (batched rendezvous)")

    # ---- regression vs the committed baseline -------------------------
    base = load(args.baseline)
    if base is None:
        warn(f"no baseline at {args.baseline}; skipping regression check")
    elif wps is None:
        warnings += 1
        warn("artifact has no worlds_per_s measurement")
    else:
        allowed = base.get("worlds_per_s")
        if allowed is None:
            warn(f"{args.baseline} has no worlds_per_s; skipping")
        else:
            floor = allowed * (1.0 - args.tolerance_pct / 100.0)
            if wps < floor:
                warnings += 1
                warn(f"minting throughput regressed: {wps:.0f} worlds/s vs "
                     f"baseline {allowed:.0f} worlds/s "
                     f"(>{args.tolerance_pct:g}% slower) — if this "
                     f"reflects a real change, re-baseline "
                     f"{args.baseline}")
            else:
                print(f"worlds/s: {wps:.0f} (baseline {allowed:.0f}, "
                      f"floor {floor:.0f}) ok")

    print(f"control-plane check: {warnings} warning(s), "
          f"tolerance {args.tolerance_pct:g}%")
    # Fail-soft by design: shared CI hardware makes absolute minting
    # rates noisy; warnings, not failures, gate this signal.
    return 0


if __name__ == "__main__":
    sys.exit(main())
