#!/usr/bin/env python3
"""Multi-tenant isolation gate over BENCH_serving.json.

Reads the `multi_tenant` section the `serving_trajectory` bench emits —
the steady tenant's client-side latency measured twice on identical
deployments: alone (`solo_*`, the baseline) and while a low-weight
burster floods at ~10x the steady tenant's solo service rate
(`steady_*`) — and checks the isolation properties the weighted-fair
admission queue exists to provide:

  * **latency isolation**: the steady tenant's p99 under the flood must
    stay within --tolerance-pct of its solo baseline (plus a small
    absolute slack for scheduler noise on sub-ms baselines);
  * **no collateral shedding**: the steady tenant must shed nothing —
    the burster's overflow is bounded per-tenant, so only the burster's
    own sub-queue fills;
  * **the burster actually sheds**: a flood at 10x share with zero
    sheds means the per-tenant bound never engaged and the leg did not
    measure isolation at all.

The artifact carries its own baseline (the solo phase runs in the same
bench invocation, on the same box), so there is no committed baseline
file to re-anchor. All checks are *soft* failures, matching
check_mttr.py: the script prints GitHub Actions `::warning::`
annotations and always exits 0 — CI boxes are noisy and the baseline is
a handful of milliseconds, so a hard gate would flake. The warnings
make every drift visible on the push that caused it.
"""

import argparse
import json
import sys


def warn(msg: str) -> None:
    print(f"::warning title=tenant-isolation::{msg}")


def load(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        warn(f"cannot read {path}: {e}")
        return None


def print_meta(doc: dict) -> None:
    meta = doc.get("meta")
    if not isinstance(meta, dict):
        print("(artifact has no meta block)")
        return
    sha = meta.get("sha") or "?"
    branch = meta.get("branch") or "?"
    run = meta.get("run_id") or "local"
    cfg = " ".join(f"{k}={v}" for k, v in sorted(meta.get("config", {}).items()))
    print(f"provenance: {sha[:12]} ({branch}, run {run}) {cfg}".rstrip())


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifact", help="path to BENCH_serving.json")
    ap.add_argument("--tolerance-pct", type=float, default=50.0,
                    help="allowed steady-p99 growth vs the solo "
                         "baseline, percent (default 50)")
    ap.add_argument("--slack-ms", type=float, default=3.0,
                    help="absolute slack added to the p99 limit, ms "
                         "(default 3; baselines are a few ms, so pure "
                         "ratios over-trigger on scheduler noise)")
    args = ap.parse_args()

    doc = load(args.artifact)
    if doc is None:
        return 0
    print_meta(doc)
    mt = doc.get("multi_tenant")
    if not isinstance(mt, dict):
        warn(f"{args.artifact} has no multi_tenant section — did the "
             f"serving_trajectory bench run?")
        return 0

    warnings = 0

    # ---- latency isolation: steady p99 vs its own solo baseline -------
    solo_p99, steady_p99 = mt.get("solo_p99_ms"), mt.get("steady_p99_ms")
    if solo_p99 is None or steady_p99 is None:
        warnings += 1
        warn("multi_tenant section is missing solo_p99_ms/steady_p99_ms")
    else:
        limit = solo_p99 * (1.0 + args.tolerance_pct / 100.0) + args.slack_ms
        if steady_p99 > limit:
            warnings += 1
            warn(f"steady tenant p99 degraded under the flood: "
                 f"{steady_p99:.2f} ms vs solo {solo_p99:.2f} ms "
                 f"(limit {limit:.2f} ms = +{args.tolerance_pct:g}% "
                 f"+ {args.slack_ms:g} ms) — weighted-fair admission "
                 f"is not isolating tenants")
        else:
            print(f"steady p99 {steady_p99:.2f} ms vs solo "
                  f"{solo_p99:.2f} ms (limit {limit:.2f} ms) ok")

    # ---- no collateral shedding ---------------------------------------
    steady_shed = mt.get("steady_shed")
    if steady_shed is None:
        warnings += 1
        warn("multi_tenant section is missing steady_shed")
    elif steady_shed > 0:
        warnings += 1
        warn(f"steady tenant shed {steady_shed} request(s) — the "
             f"burster's overflow leaked out of its per-tenant bound")
    else:
        print("steady tenant shed nothing ok")

    # ---- the burster must actually overflow its bound -----------------
    burst_shed = mt.get("burst_shed") or 0
    burst_submitted = mt.get("burst_submitted") or 0
    if burst_shed < 1:
        warnings += 1
        warn(f"burster shed nothing ({burst_submitted} submitted) — the "
             f"per-tenant bound never engaged, so the leg did not "
             f"measure isolation")
    else:
        print(f"burster: {burst_submitted} submitted, "
              f"{mt.get('burst_completed') or 0} completed, "
              f"{burst_shed} shed ok")

    print(f"tenant isolation check: {warnings} warning(s), tolerance "
          f"{args.tolerance_pct:g}% + {args.slack_ms:g} ms")
    # Fail-soft by design: the baseline is a few milliseconds measured
    # on a shared CI box; warnings, not failures, gate this signal.
    return 0


if __name__ == "__main__":
    sys.exit(main())
