#!/usr/bin/env python3
"""Recovery-latency (MTTR) regression gate over BENCH_serving.json.

Reads the `mttr` section the `serving_trajectory` bench emits — the
kill->Recovered wall-time distribution (p50/p99/max over repeated
kills) for two legs: `spares0` (cold respawn, weight cache off — the
pre-pool recovery path) and `spares2` (pre-warmed spare pool + host
weight cache) — and checks it two ways:

  * **pool efficacy**: the spares leg's p99 must be strictly below the
    cold leg's p99 (that the pool removes recovery latency is the whole
    point; a run where it doesn't is either a regression in promotion
    or a broken bench);
  * **regression vs baseline**: each leg's p50/p99 is compared against
    the committed `tools/mttr_baseline.json`; a measurement more than
    --tolerance-pct worse than baseline (default 25%) is flagged.

Both checks are *soft* failures, matching check_crossover.py: the
script prints GitHub Actions `::warning::` annotations and always
exits 0 — CI boxes are noisy and MTTR includes watchdog detection
time, so a hard gate would flake. The warnings make every drift
visible on the push that caused it.

The artifact's `meta` block (commit / branch / run / knobs) is printed
for provenance and skipped as data. Re-baseline by copying the measured
numbers from a healthy run into tools/mttr_baseline.json.
"""

import argparse
import json
import sys

LEGS = ("spares0", "spares2")
QUANTILES = ("p50_ms", "p99_ms")


def warn(msg: str) -> None:
    print(f"::warning title=mttr::{msg}")


def load(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        warn(f"cannot read {path}: {e}")
        return None


def print_meta(doc: dict) -> None:
    meta = doc.get("meta")
    if not isinstance(meta, dict):
        print("(artifact has no meta block)")
        return
    sha = meta.get("sha") or "?"
    branch = meta.get("branch") or "?"
    run = meta.get("run_id") or "local"
    cfg = " ".join(f"{k}={v}" for k, v in sorted(meta.get("config", {}).items()))
    print(f"provenance: {sha[:12]} ({branch}, run {run}) {cfg}".rstrip())


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifact", help="path to BENCH_serving.json")
    ap.add_argument("--baseline", default="tools/mttr_baseline.json",
                    help="committed MTTR baseline (default "
                         "tools/mttr_baseline.json)")
    ap.add_argument("--tolerance-pct", type=float, default=25.0,
                    help="regression threshold vs baseline, percent "
                         "(default 25)")
    args = ap.parse_args()

    doc = load(args.artifact)
    if doc is None:
        return 0
    print_meta(doc)
    mttr = doc.get("mttr")
    if not isinstance(mttr, dict):
        warn(f"{args.artifact} has no mttr section — did the "
             f"serving_trajectory bench run?")
        return 0

    warnings = 0

    # ---- pool efficacy: spares must beat cold respawn -----------------
    cold, warm = (mttr.get(leg) or {} for leg in LEGS)
    cold_p99, warm_p99 = cold.get("p99_ms"), warm.get("p99_ms")
    if cold_p99 is None or warm_p99 is None:
        warnings += 1
        warn("mttr section is missing a leg (wanted spares0 + spares2)")
    elif not warm_p99 < cold_p99:
        warnings += 1
        warn(f"spare pool did not beat cold respawn: spares2 p99 "
             f"{warm_p99:.1f} ms >= spares0 p99 {cold_p99:.1f} ms "
             f"(promotion should be strictly faster than a cold "
             f"weight load)")
    if (warm.get("promoted") or 0) < 1:
        warnings += 1
        warn("spares2 leg recorded zero promotions — recoveries took the "
             "cold path, so the leg did not measure the pool at all")

    # ---- regression vs the committed baseline -------------------------
    base = load(args.baseline)
    if base is None:
        warn(f"no baseline at {args.baseline}; skipping regression check")
    else:
        factor = 1.0 + args.tolerance_pct / 100.0
        for leg in LEGS:
            for q in QUANTILES:
                measured = (mttr.get(leg) or {}).get(q)
                allowed = (base.get(leg) or {}).get(q)
                if measured is None or allowed is None:
                    continue
                if measured > allowed * factor:
                    warnings += 1
                    warn(f"{leg} {q} regressed: {measured:.1f} ms vs "
                         f"baseline {allowed:.1f} ms "
                         f"(>{args.tolerance_pct:g}% worse) — if this "
                         f"reflects a real change, re-baseline "
                         f"{args.baseline}")
                else:
                    print(f"{leg} {q}: {measured:.1f} ms "
                          f"(baseline {allowed:.1f} ms, "
                          f"limit {allowed * factor:.1f} ms) ok")

    kills = (mttr.get("spares0") or {}).get("kills")
    print(f"mttr check: {kills} kill(s)/leg, {warnings} warning(s), "
          f"tolerance {args.tolerance_pct:g}%")
    # Fail-soft by design: MTTR includes detection latency and CI
    # hardware noise; warnings, not failures, gate this signal.
    return 0


if __name__ == "__main__":
    sys.exit(main())
