#!/usr/bin/env python3
"""Compare the measured collective crossovers against the configured
policy defaults.

Reads the CSV emitted by `cargo bench --bench ablation_collectives`
(columns: op,world,hosts,bytes,flat_ms,ring_ms,hier_ms,speedup_ring,
speedup_hier,auto — a blank timing cell means the algorithm is not
selectable there, e.g. ring past RING_MAX_WORLD ranks or hier on a
single host) and checks:

  * flat->ring, single-host rows: the byte knee — the smallest payload
    where the ring beats the flat star at ring-eligible world sizes —
    against RING_MIN_BYTES; and the world knee — whether the ring
    already wins below RING_MIN_WORLD, or still loses at it, on the
    largest measured payload;
  * ring->hier, multi-host rows: from --hier-min-world ranks across
    >= 2 simulated hosts the hierarchical algorithm must beat whichever
    of ring/flat is its best alternative on the largest payload (this is
    the knee `Auto` encodes by going hier whenever the world spans
    hosts); past RING_MAX_WORLD, where the ring cell is blank, hier
    must beat flat outright.

Disagreements are *soft* failures: the script prints GitHub Actions
`::warning::` annotations (so the knee drift is visible on every push
without blocking merges — CI hardware is noisy) and always exits 0.
Tune the configured side via --min-world/--min-bytes, which should
mirror `CollAlgo::RING_MIN_WORLD`/`RING_MIN_BYTES` (or the MW_RING_MIN_*
env overrides the bench ran under).

Pass --json BENCH_collectives.json to print the artifact's `meta`
provenance block (commit / branch / CI run / knob config) alongside the
check, so a warning in the log is attributable to the exact run that
produced the numbers. The `meta` key is provenance, not data: any
scan of the artifact's sections must skip it.
"""

import argparse
import csv
import json
import sys
from collections import defaultdict

# Artifact keys that describe the run rather than carrying measurements;
# consumers iterating artifact sections must skip these.
META_KEYS = {"meta", "bench", "quick"}


def print_meta(path: str) -> None:
    """Best-effort provenance print from a BENCH_*.json artifact."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"(no provenance: {path}: {e})")
        return
    meta = doc.get("meta")
    if not isinstance(meta, dict):
        print(f"(no provenance: {path} has no meta block)")
        return
    sha = meta.get("sha") or "?"
    branch = meta.get("branch") or "?"
    run = meta.get("run_id") or "local"
    cfg = " ".join(f"{k}={v}" for k, v in sorted(meta.get("config", {}).items()))
    print(f"provenance: {sha[:12]} ({branch}, run {run}) {cfg}".rstrip())
    sections = [k for k in doc if k not in META_KEYS]
    print(f"artifact sections (meta skipped): {', '.join(sorted(sections))}")

# One algorithm must beat another by this factor before we call it a
# win (CI noise).
WIN = 1.10


def warn(msg: str) -> None:
    print(f"::warning title=collective crossover::{msg}")


def fcell(row, key):
    """A timing cell: float ms, or None when blank (not selectable)."""
    v = (row.get(key) or "").strip()
    return float(v) if v else None


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("csv", help="path to ablation_collectives.csv")
    ap.add_argument("--min-world", type=int, default=4,
                    help="configured RING_MIN_WORLD (default 4)")
    ap.add_argument("--min-bytes", type=int, default=1 << 20,
                    help="configured RING_MIN_BYTES (default 1 MiB)")
    ap.add_argument("--hier-min-world", type=int, default=16,
                    help="world size from which hier must win multi-host "
                         "rows (default 16)")
    ap.add_argument("--tolerance", type=float, default=4.0,
                    help="acceptable knee drift factor (default 4x)")
    ap.add_argument("--json", default=None,
                    help="optional BENCH_collectives.json for the meta "
                         "provenance block (printed, then skipped)")
    args = ap.parse_args()

    if args.json:
        print_meta(args.json)

    # single[op][world] = [(bytes, flat_ms, ring_ms)] — hosts == 1 rows.
    # multi[op][(world, hosts)] = [(bytes, flat_ms, ring_ms|None, hier_ms)]
    single = defaultdict(lambda: defaultdict(list))
    multi = defaultdict(lambda: defaultdict(list))
    with open(args.csv, newline="") as f:
        for r in csv.DictReader(f):
            hosts = int(r.get("hosts") or 1)
            flat, ring, hier = (fcell(r, k) for k in ("flat_ms", "ring_ms", "hier_ms"))
            if hosts <= 1:
                if flat is not None and ring is not None:
                    single[r["op"]][int(r["world"])].append((int(r["bytes"]), flat, ring))
            elif flat is not None and hier is not None:
                multi[r["op"]][(int(r["world"]), hosts)].append(
                    (int(r["bytes"]), flat, ring, hier)
                )
    if not single and not multi:
        warn(f"{args.csv} contained no measurements")
        return 0

    warnings = 0

    # ---- flat -> ring knee, single host -------------------------------
    for op, by_world in sorted(single.items()):
        for world, cells in sorted(by_world.items()):
            cells.sort()
            wins = [b for b, flat, ring in cells if flat > ring * WIN]
            knee = wins[0] if wins else None
            if world >= args.min_world:
                if knee is None:
                    biggest = cells[-1][0]
                    if biggest >= args.min_bytes:
                        warnings += 1
                        warn(
                            f"{op} world={world}: ring never beat flat up to "
                            f"{biggest} B, but RING_MIN_BYTES={args.min_bytes} "
                            f"would ring there — consider raising the "
                            f"{op.upper()} row of the policy table"
                        )
                elif knee > args.min_bytes * args.tolerance:
                    warnings += 1
                    warn(
                        f"{op} world={world}: measured knee {knee} B is "
                        f">{args.tolerance:g}x the configured "
                        f"RING_MIN_BYTES={args.min_bytes} — Auto rings too early"
                    )
                elif knee * args.tolerance < args.min_bytes:
                    warnings += 1
                    warn(
                        f"{op} world={world}: measured knee {knee} B is "
                        f"<1/{args.tolerance:g} of the configured "
                        f"RING_MIN_BYTES={args.min_bytes} — Auto rings too late"
                    )
            else:
                # Below the world threshold Auto always goes flat; flag it
                # if the ring decisively wins big payloads here anyway.
                big = [c for c in cells if c[0] >= args.min_bytes]
                if big and all(flat > ring * WIN for _, flat, ring in big):
                    warnings += 1
                    warn(
                        f"{op} world={world}: ring already wins every "
                        f">= {args.min_bytes} B cell below "
                        f"RING_MIN_WORLD={args.min_world} — consider lowering "
                        f"the {op.upper()} row of the policy table"
                    )

    # ---- ring -> hier knee, multi host --------------------------------
    for op, by_shape in sorted(multi.items()):
        for (world, hosts), cells in sorted(by_shape.items()):
            cells.sort()
            bytes_, flat, ring, hier = cells[-1]  # largest payload
            best_alt, alt_name = (
                (ring, "ring") if ring is not None and ring < flat else (flat, "flat")
            )
            if world >= args.hier_min_world and hosts >= 2:
                if best_alt < hier * WIN:
                    warnings += 1
                    warn(
                        f"{op} world={world} hosts={hosts}: hier "
                        f"({hier:.3f} ms) did not beat {alt_name} "
                        f"({best_alt:.3f} ms) at {bytes_} B — Auto goes hier "
                        f"on every multi-host world this size, so the "
                        f"hierarchical path should be winning here"
                    )
            if ring is None and world > 128 and hier > flat * args.tolerance:
                warnings += 1
                warn(
                    f"{op} world={world} hosts={hosts}: past RING_MAX_WORLD "
                    f"hier ({hier:.3f} ms) loses badly to flat "
                    f"({flat:.3f} ms) — the only non-flat choice is slower "
                    f"than the fallback"
                )

    n_series = sum(len(w) for w in single.values()) + sum(len(w) for w in multi.values())
    print(
        f"crossover check: {n_series} (op, world[, hosts]) series, "
        f"{warnings} disagreement(s) with RING_MIN_WORLD={args.min_world} "
        f"RING_MIN_BYTES={args.min_bytes} HIER_MIN_WORLD={args.hier_min_world}"
    )
    # Fail-soft by design: the knee depends on CI hardware of the day.
    return 0


if __name__ == "__main__":
    sys.exit(main())
