#!/usr/bin/env python3
"""Compare the measured flat->ring crossover against the configured
policy defaults.

Reads the CSV emitted by `cargo bench --bench ablation_collectives`
(columns: op,world,bytes,flat_ms,ring_ms,speedup,auto) and checks, per
collective:

  * the byte knee — the smallest payload where the ring beats the flat
    star at ring-eligible world sizes — against RING_MIN_BYTES;
  * the world knee — whether the ring already wins below RING_MIN_WORLD,
    or still loses at it, on the largest measured payload.

Disagreements are *soft* failures: the script prints GitHub Actions
`::warning::` annotations (so the knee drift is visible on every push
without blocking merges — CI hardware is noisy) and always exits 0.
Tune the configured side via --min-world/--min-bytes, which should
mirror `CollAlgo::RING_MIN_WORLD`/`RING_MIN_BYTES` (or the MW_RING_MIN_*
env overrides the bench ran under).
"""

import argparse
import csv
import sys
from collections import defaultdict

# Ring must beat flat by this factor before we call it a win (CI noise).
WIN = 1.10


def warn(msg: str) -> None:
    print(f"::warning title=collective crossover::{msg}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("csv", help="path to ablation_collectives.csv")
    ap.add_argument("--min-world", type=int, default=4,
                    help="configured RING_MIN_WORLD (default 4)")
    ap.add_argument("--min-bytes", type=int, default=1 << 20,
                    help="configured RING_MIN_BYTES (default 1 MiB)")
    ap.add_argument("--tolerance", type=float, default=4.0,
                    help="acceptable knee drift factor (default 4x)")
    args = ap.parse_args()

    # rows[op][world] = sorted list of (bytes, flat_ms, ring_ms)
    rows = defaultdict(lambda: defaultdict(list))
    with open(args.csv, newline="") as f:
        for r in csv.DictReader(f):
            rows[r["op"]][int(r["world"])].append(
                (int(r["bytes"]), float(r["flat_ms"]), float(r["ring_ms"]))
            )
    if not rows:
        warn(f"{args.csv} contained no measurements")
        return 0

    warnings = 0
    for op, by_world in sorted(rows.items()):
        for world, cells in sorted(by_world.items()):
            cells.sort()
            wins = [b for b, flat, ring in cells if flat > ring * WIN]
            knee = wins[0] if wins else None
            if world >= args.min_world:
                if knee is None:
                    biggest = cells[-1][0]
                    if biggest >= args.min_bytes:
                        warnings += 1
                        warn(
                            f"{op} world={world}: ring never beat flat up to "
                            f"{biggest} B, but RING_MIN_BYTES={args.min_bytes} "
                            f"would ring there — consider raising the "
                            f"{op.upper()} row of the policy table"
                        )
                elif knee > args.min_bytes * args.tolerance:
                    warnings += 1
                    warn(
                        f"{op} world={world}: measured knee {knee} B is "
                        f">{args.tolerance:g}x the configured "
                        f"RING_MIN_BYTES={args.min_bytes} — Auto rings too early"
                    )
                elif knee * args.tolerance < args.min_bytes:
                    warnings += 1
                    warn(
                        f"{op} world={world}: measured knee {knee} B is "
                        f"<1/{args.tolerance:g} of the configured "
                        f"RING_MIN_BYTES={args.min_bytes} — Auto rings too late"
                    )
            else:
                # Below the world threshold Auto always goes flat; flag it
                # if the ring decisively wins big payloads here anyway.
                big = [c for c in cells if c[0] >= args.min_bytes]
                if big and all(flat > ring * WIN for _, flat, ring in big):
                    warnings += 1
                    warn(
                        f"{op} world={world}: ring already wins every "
                        f">= {args.min_bytes} B cell below "
                        f"RING_MIN_WORLD={args.min_world} — consider lowering "
                        f"the {op.upper()} row of the policy table"
                    )

    print(
        f"crossover check: {sum(len(w) for w in rows.values())} (op, world) "
        f"series, {warnings} disagreement(s) with "
        f"RING_MIN_WORLD={args.min_world} RING_MIN_BYTES={args.min_bytes}"
    )
    # Fail-soft by design: the knee depends on CI hardware of the day.
    return 0


if __name__ == "__main__":
    sys.exit(main())
